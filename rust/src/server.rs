//! Line-protocol TCP server + client for the serving example.
//!
//! Offline build: no tokio, so the server is a plain `std::net` design —
//! one acceptor thread, per-connection reader threads feeding an mpsc
//! channel, and the engine thread draining it. This mirrors the paper's
//! single-device edge deployment (one model, one engine loop, multiple
//! lightweight clients).
//!
//! Protocol: one JSON object per line.
//!
//! ```text
//! → {"id": 1, "prompt": "the model", "max_tokens": 32, "temperature": 0.8}
//! ← {"id": 1, "text": "...", "tokens": 32, "finish": "length",
//!    "first_token_ms": 12.3, "decode_ms": 45.6}
//! ```

use crate::coordinator::{Backend, Engine, Request, Response};
use crate::corpus::ByteTokenizer;
use crate::json::{self, Value};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Parse one request line. Public for tests and the client.
pub fn parse_request(line: &str, next_id: u64) -> Result<Request> {
    let v = Value::parse(line)?;
    parse_request_value(&v, next_id)
}

/// Build a [`Request`] from an already-parsed line (the connection
/// reader parses each line exactly once and branches on the result).
pub fn parse_request_value(v: &Value, next_id: u64) -> Result<Request> {
    let prompt_text = v.get("prompt")?.as_str()?.to_string();
    let prompt = ByteTokenizer.encode(&prompt_text);
    if prompt.is_empty() {
        return Err(Error::InvalidArg("empty prompt".into()));
    }
    let id = v
        .get_opt("id")
        .map(|x| x.as_f64().map(|n| n as u64))
        .transpose()?
        .unwrap_or(next_id);
    Ok(Request {
        id,
        prompt,
        max_new_tokens: v
            .get_opt("max_tokens")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(32),
        temperature: v
            .get_opt("temperature")
            .map(|x| x.as_f64())
            .transpose()?
            .unwrap_or(0.0) as f32,
        top_k: v
            .get_opt("top_k")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(0),
        stop_token: Some(u32::from(b'.')),
        enqueued_at: None,
    })
}

/// Serialize a response line.
pub fn format_response(r: &Response) -> String {
    let text = ByteTokenizer.decode(&r.tokens);
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        ("text", json::s(&text)),
        ("tokens", json::num(r.tokens.len() as f64)),
        (
            "finish",
            json::s(match r.finish_reason {
                crate::coordinator::request::FinishReason::Length => "length",
                crate::coordinator::request::FinishReason::Stop => "stop",
                crate::coordinator::request::FinishReason::Capacity => "capacity",
            }),
        ),
        (
            "first_token_ms",
            json::num(r.timing.first_token.as_secs_f64() * 1e3),
        ),
        ("decode_ms", json::num(r.timing.decode.as_secs_f64() * 1e3)),
    ])
    .to_json()
}

enum Incoming {
    Req(Request, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
    Bad(String, mpsc::Sender<String>),
}

/// Serialize an engine-stats snapshot (the `{"stats": true}` admin
/// line's reply): serving counters plus live occupancy, so an operator
/// can watch a streaming-loaded server warm up without a side channel.
/// When the backend serves weights through a residency cache
/// ([`crate::residency`]), the cache's hit/miss/evict counters and
/// byte occupancy ride along under `cache_*` keys; when it prefetches
/// decode-ahead ([`crate::residency::prefetch`]), the prefetcher's
/// scheduled/completed/hit/wait counters ride along under `prefetch_*`
/// keys.
pub fn format_stats<B: Backend>(engine: &Engine<B>) -> String {
    let s = engine.stats();
    let q = engine.queue_stats();
    let mut fields = vec![
        ("completed", json::num(s.completed as f64)),
        ("tokens", json::num(s.tokens as f64)),
        ("decode_steps", json::num(s.decode_steps as f64)),
        ("mean_occupancy", json::num(s.mean_occupancy())),
        ("active_slots", json::num(engine.active() as f64)),
        ("queue_depth", json::num(q.depth as f64)),
        ("admitted", json::num(q.admitted as f64)),
        ("rejected", json::num(q.rejected as f64)),
    ];
    if let Some(c) = engine.residency() {
        fields.push(("cache_hits", json::num(c.hits as f64)));
        fields.push(("cache_misses", json::num(c.misses as f64)));
        fields.push(("cache_evictions", json::num(c.evictions as f64)));
        fields.push(("cache_resident_bytes", json::num(c.resident_bytes as f64)));
        fields.push((
            "cache_peak_resident_bytes",
            json::num(c.peak_resident_bytes as f64),
        ));
        fields.push(("cache_budget_bytes", json::num(c.budget_bytes as f64)));
        fields.push(("cache_pinned_layers", json::num(c.pinned_layers as f64)));
    }
    if let Some(p) = engine.prefetch() {
        fields.push(("prefetch_scheduled", json::num(p.scheduled as f64)));
        fields.push(("prefetch_completed", json::num(p.completed as f64)));
        fields.push(("prefetch_hits", json::num(p.hits as f64)));
        fields.push(("prefetch_waits", json::num(p.waits as f64)));
        fields.push(("prefetch_sync_faults", json::num(p.sync_faults as f64)));
    }
    json::obj(fields).to_json()
}

/// Serve an engine over TCP until `stop` flips. Returns total requests
/// served. Spawns one thread per connection (edge workloads: few
/// clients) plus the engine loop on the calling thread.
pub fn serve<B: Backend>(
    engine: &mut Engine<B>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<u64> {
    listener.set_nonblocking(true)?;
    let (tx, rx) = mpsc::channel::<Incoming>();

    // Acceptor thread: owns the listener, spawns per-connection readers.
    let acc_stop = stop.clone();
    let acceptor = std::thread::spawn(move || {
        let mut conns = Vec::new();
        while !acc_stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let tx = tx.clone();
                    let stop = acc_stop.clone();
                    conns.push(std::thread::spawn(move || read_conn(stream, tx, stop)));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        for c in conns {
            let _ = c.join();
        }
    });

    // Engine loop: drain incoming, step, route responses.
    let mut next_id: u64 = 1;
    let mut waiters: Vec<(u64, mpsc::Sender<String>)> = Vec::new();
    let mut served = 0u64;
    while !stop.load(Ordering::Relaxed) {
        let mut idle = true;
        while let Ok(msg) = rx.try_recv() {
            idle = false;
            match msg {
                Incoming::Req(req, reply) => {
                    let id = req.id.max(next_id);
                    next_id = id + 1;
                    let mut req = req;
                    req.id = id;
                    match engine.submit(req) {
                        Ok(()) => waiters.push((id, reply)),
                        Err(e) => {
                            let _ = reply.send(format!(
                                r#"{{"error":"{}"}}"#,
                                e.to_string().replace('"', "'")
                            ));
                        }
                    }
                }
                Incoming::Stats(reply) => {
                    let _ = reply.send(format_stats(engine));
                }
                Incoming::Bad(err, reply) => {
                    let _ = reply.send(format!(r#"{{"error":"{err}"}}"#));
                }
            }
        }
        if engine.has_work() {
            idle = false;
            for resp in engine.step()? {
                served += 1;
                if let Some(i) = waiters.iter().position(|(id, _)| *id == resp.id) {
                    let (_, reply) = waiters.swap_remove(i);
                    let _ = reply.send(format_response(&resp));
                }
            }
        }
        if idle {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    drop(rx);
    let _ = acceptor.join();
    Ok(served)
}

fn read_conn(stream: TcpStream, tx: mpsc::Sender<Incoming>, stop: Arc<AtomicBool>) {
    let peer_write = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // Read with a timeout so a long-lived idle client can't pin this
    // thread past server shutdown.
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .ok();
    let (reply_tx, reply_rx) = mpsc::channel::<String>();
    // Writer thread serializes replies back to this connection.
    let writer = std::thread::spawn(move || {
        let mut w = peer_write;
        while let Ok(line) = reply_rx.recv() {
            if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                break;
            }
            let _ = w.flush();
        }
    });
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    // Parse once; `{"stats": true}` is the admin line,
                    // anything else is a generation request.
                    let msg = match Value::parse(trimmed) {
                        Ok(ref v)
                            if matches!(v.get_opt("stats"), Some(Value::Bool(true))) =>
                        {
                            Incoming::Stats(reply_tx.clone())
                        }
                        Ok(ref v) => match parse_request_value(v, 0) {
                            Ok(req) => Incoming::Req(req, reply_tx.clone()),
                            Err(e) => Incoming::Bad(
                                e.to_string().replace('"', "'"),
                                reply_tx.clone(),
                            ),
                        },
                        Err(e) => Incoming::Bad(
                            e.to_string().replace('"', "'"),
                            reply_tx.clone(),
                        ),
                    };
                    if tx.send(msg).is_err() {
                        break;
                    }
                }
                line.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Timeout tick: keep any partial line and re-check stop.
                continue;
            }
            Err(_) => break,
        }
    }
    drop(reply_tx);
    let _ = writer.join();
}

/// Blocking client for the line protocol (used by examples/benches).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a server.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    /// Send one request line and wait for the reply line.
    pub fn request(&mut self, prompt: &str, max_tokens: usize, temperature: f32) -> Result<Value> {
        let line = json::obj(vec![
            ("prompt", json::s(prompt)),
            ("max_tokens", json::num(max_tokens as f64)),
            ("temperature", json::num(temperature as f64)),
        ])
        .to_json();
        self.roundtrip(&line)
    }

    /// Request the server's engine-stats snapshot.
    pub fn stats(&mut self) -> Result<Value> {
        self.roundtrip(r#"{"stats":true}"#)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            return Err(Error::Engine("server closed connection".into()));
        }
        Value::parse(reply.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EngineConfig, MockBackend};

    #[test]
    fn parse_request_accepts_minimal_and_full() {
        let r = parse_request(r#"{"prompt":"hi"}"#, 42).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.prompt, vec![104, 105]);
        assert_eq!(r.max_new_tokens, 32);
        let r = parse_request(
            r#"{"id":7,"prompt":"x","max_tokens":5,"temperature":0.5,"top_k":3}"#,
            0,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.max_new_tokens, 5);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.top_k, 3);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"prompt":""}"#, 1).is_err());
        assert!(parse_request(r#"{"no_prompt":1}"#, 1).is_err());
    }

    #[test]
    fn format_response_roundtrips_as_json() {
        let r = Response {
            id: 3,
            tokens: vec![104, 105],
            finish_reason: crate::coordinator::request::FinishReason::Length,
            timing: Default::default(),
        };
        let v = Value::parse(&format_response(&r)).unwrap();
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("text").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
    }

    #[test]
    fn end_to_end_over_loopback_with_mock_backend() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("ab", 4, 0.0).unwrap();
        assert_eq!(reply.get("tokens").unwrap().as_usize().unwrap(), 4);
        let reply2 = c.request("cd", 2, 0.0).unwrap();
        assert_eq!(reply2.get("tokens").unwrap().as_usize().unwrap(), 2);

        // Admin stats line reports the two completed requests.
        let stats = c.stats().unwrap();
        assert_eq!(stats.get("completed").unwrap().as_usize().unwrap(), 2);
        assert_eq!(stats.get("tokens").unwrap().as_usize().unwrap(), 6);
        assert_eq!(stats.get("active_slots").unwrap().as_usize().unwrap(), 0);
        assert_eq!(stats.get("rejected").unwrap().as_usize().unwrap(), 0);

        // `"stats": false` is NOT the admin line: it falls through to
        // request parsing and earns an error (no prompt), not a snapshot.
        let not_stats = c.roundtrip(r#"{"stats":false}"#).unwrap();
        assert!(not_stats.get_opt("error").is_some(), "{not_stats:?}");

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 2);
    }

    #[test]
    fn format_stats_is_valid_json_with_counters() {
        let engine = Engine::new(MockBackend::new(2, 32, 128), EngineConfig::default());
        let v = Value::parse(&format_stats(&engine)).unwrap();
        assert_eq!(v.get("completed").unwrap().as_usize().unwrap(), 0);
        assert_eq!(v.get("queue_depth").unwrap().as_usize().unwrap(), 0);
        assert!(v.get("mean_occupancy").unwrap().as_f64().unwrap() >= 0.0);
        // Fully-resident backends have no residency cache to report.
        assert!(v.get_opt("cache_hits").is_none());
    }

    /// The acceptance loop for the weight-residency subsystem: a model
    /// whose decoded weights exceed the byte budget serves over TCP,
    /// and the `{"stats":true}` admin line carries the cache counters.
    #[test]
    fn stats_line_surfaces_residency_counters_over_loopback() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{ResidentDigestBackend, ResidentWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(8, 0xFEED);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let bytes: Vec<usize> = model.layers.iter().map(|m| m.n_symbols).collect();
        let largest = *bytes.iter().max().unwrap();
        let total: usize = bytes.iter().sum();
        let budget = largest.max(total / 2);
        assert!(budget < total, "model must exceed the budget");
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = ResidentWeightSet::new(src, budget, Vec::new()).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                ResidentDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("residency", 4, 0.0).unwrap();
        // Token values are digest-driven, so generation may stop early
        // on the protocol's '.' stop token; at least one token arrives.
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);

        let stats = c.stats().unwrap();
        assert!(stats.get("cache_misses").unwrap().as_usize().unwrap() > 0);
        assert!(
            stats.get("cache_evictions").unwrap().as_usize().unwrap() > 0,
            "under-budget serving must evict"
        );
        let peak = stats
            .get("cache_peak_resident_bytes")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(peak <= budget, "peak {peak} must respect budget {budget}");
        assert_eq!(
            stats.get("cache_budget_bytes").unwrap().as_usize().unwrap(),
            budget
        );

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1);
    }

    /// The decode-ahead acceptance loop: a prefetching backend serves
    /// over TCP and the `{"stats":true}` admin line carries both the
    /// `cache_*` and the `prefetch_*` counter families.
    #[test]
    fn stats_line_surfaces_prefetch_counters_over_loopback() {
        use crate::pipeline::synthetic_layers;
        use crate::quant::BitWidth;
        use crate::residency::{PrefetchConfig, PrefetchingDigestBackend, PrefetchingWeightSet};
        use crate::store::{compress, SegmentSource};

        let layers = synthetic_layers(8, 0xFEED);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let total: usize = model.layers.iter().map(|m| m.n_symbols).sum();
        let largest = model.layers.iter().map(|m| m.n_symbols).max().unwrap();
        // Whole model plus the decode-ahead floor (window 2 + active).
        let budget = total.max(3 * largest);
        let src = Arc::new(SegmentSource::from_model(Arc::new(model)));
        let ws = PrefetchingWeightSet::new(src, budget, Vec::new(), PrefetchConfig::default())
            .unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let server = std::thread::spawn(move || {
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 32, 256),
                EngineConfig::default(),
            );
            serve(&mut engine, listener, stop2).unwrap()
        });

        let mut c = Client::connect(&addr).unwrap();
        let reply = c.request("decode ahead", 4, 0.0).unwrap();
        assert!(reply.get("tokens").unwrap().as_usize().unwrap() >= 1);

        let stats = c.stats().unwrap();
        // Residency family still present…
        assert!(stats.get("cache_misses").unwrap().as_usize().unwrap() > 0);
        // …and the prefetch family rides along. The walk schedules
        // ahead on every consumed layer; how many jobs the pool won
        // against the consumer is timing-dependent, so only
        // `scheduled` has a guaranteed floor.
        assert!(stats.get("prefetch_scheduled").unwrap().as_usize().unwrap() > 0);
        for key in [
            "prefetch_completed",
            "prefetch_hits",
            "prefetch_waits",
            "prefetch_sync_faults",
        ] {
            assert!(stats.get(key).is_ok(), "missing {key}: {stats:?}");
        }

        stop.store(true, Ordering::Relaxed);
        let served = server.join().unwrap();
        assert_eq!(served, 1);
    }
}
