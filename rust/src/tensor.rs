//! Minimal dense tensors shared by the compression pipeline.
//!
//! The coordinator only ever needs two element types: `f32` master
//! weights (cloud side) and `u8` quantization symbols (both uint8 levels
//! and uint4 levels stored one-per-byte before packing/encoding). A
//! full ndarray library would be overkill; shape bookkeeping plus a few
//! constructors is all the pipeline touches.

use crate::{Error, Result};

/// Tensor shape (row-major).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Rank.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Self {
        Shape(v.to_vec())
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, "x")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// Dense row-major `f32` tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    shape: Shape,
    data: Vec<f32>,
}

impl TensorF32 {
    /// Construct from shape + data; the lengths must agree.
    pub fn new(shape: impl Into<Shape>, data: Vec<f32>) -> Result<Self> {
        let shape = shape.into();
        if shape.numel() != data.len() {
            return Err(Error::InvalidArg(format!(
                "shape {shape} wants {} elements, got {}",
                shape.numel(),
                data.len()
            )));
        }
        Ok(TensorF32 { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: impl Into<Shape>) -> Self {
        let shape = shape.into();
        let n = shape.numel();
        TensorF32 {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Shape accessor.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Flat data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// (min, max) over the data; `None` for empty tensors.
    pub fn min_max(&self) -> Option<(f32, f32)> {
        if self.data.is_empty() {
            return None;
        }
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &self.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        Some((mn, mx))
    }
}

/// Dense row-major `u8` tensor of quantization symbols.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorU8 {
    shape: Shape,
    data: Vec<u8>,
}

impl TensorU8 {
    /// Construct from shape + data; the lengths must agree.
    pub fn new(shape: impl Into<Shape>, data: Vec<u8>) -> Result<Self> {
        let shape = shape.into();
        if shape.numel() != data.len() {
            return Err(Error::InvalidArg(format!(
                "shape {shape} wants {} elements, got {}",
                shape.numel(),
                data.len()
            )));
        }
        Ok(TensorU8 { shape, data })
    }

    /// Shape accessor.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Flat data.
    pub fn data(&self) -> &[u8] {
        &self.data
    }

    /// Consume into the flat buffer.
    pub fn into_data(self) -> Vec<u8> {
        self.data
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_numel_and_display() {
        let s: Shape = vec![2, 3, 4].into();
        assert_eq!(s.numel(), 24);
        assert_eq!(s.rank(), 3);
        assert_eq!(s.to_string(), "[2x3x4]");
    }

    #[test]
    fn tensor_rejects_mismatched_data() {
        assert!(TensorF32::new(vec![2, 2], vec![1.0; 3]).is_err());
        assert!(TensorU8::new(vec![5], vec![0u8; 4]).is_err());
    }

    #[test]
    fn min_max() {
        let t = TensorF32::new(vec![4], vec![-1.5, 0.0, 3.25, 2.0]).unwrap();
        assert_eq!(t.min_max(), Some((-1.5, 3.25)));
        assert_eq!(TensorF32::zeros(vec![0]).min_max(), None);
    }
}
