//! Latency model — regenerates the paper's Table II rows from a workload
//! description plus a [`Profile`](super::Profile).
//!
//! Phase models (see module docs in [`super`]):
//!
//! * `pre-fill(L tokens)` = `compute(2·P·L flops) + stream(weight_bytes)
//!   + unpack`, compute-dominated for long prompts;
//! * `token generation` = `stream(weight_bytes) + unpack + compute(2·P)`,
//!   bandwidth-dominated — this is where effective-bit reduction pays;
//! * `parallel decode` = per-core symbol throughput × imbalance, once per
//!   sequence;
//! * `first token` = decode (if Huffman) + pre-fill + one generation step;
//! * `fault-in` = the residency-cache tax: with `R` of `L` decoded
//!   layers *pinned* resident, each token step re-decodes the missing
//!   `(L-R)/L` fraction ([`LatencyModel::fault_in_per_token`]; pass
//!   `R = 0` for a pure-LRU cache on a cyclic scan);
//! * `overlapped fault-in` = the decode-ahead pipeline
//!   (`residency::prefetch`): the fault bill hides behind compute, so a
//!   token costs `max(compute, decode)` instead of their sum
//!   ([`LatencyModel::overlapped_token_gen`]).

use super::Profile;

/// What gets executed: a model and a request shape.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Total parameter count `P`.
    pub n_params: usize,
    /// Bytes that must move from DRAM per full weight pass *during
    /// compute* (after any upfront decode): `P · bits/8` for fixed-width,
    /// or the Huffman-decoded working width if weights are kept packed.
    pub weight_bytes_per_pass: usize,
    /// Bytes of the stored (possibly Huffman-encoded) weights that are
    /// read once at load/decode time.
    pub stored_bytes: usize,
    /// Prompt length in tokens (pre-fill).
    pub prefill_tokens: usize,
    /// Whether an upfront Huffman decode is required (w/ Huffman rows).
    pub huffman: bool,
    /// Decode threads (`T`).
    pub threads: usize,
    /// Load-balance factor from the segment scheduler (≥ 1).
    pub imbalance: f64,
    /// Relative ALU cost of this precision's matmul vs int8 (the
    /// paper's own prefill rows imply int4 ops run ~2.8× faster on the
    /// Jetson: 9.69 s vs 27.10 s for the same prompt).
    pub compute_scale: f64,
}

/// Cost of one phase, seconds, with its dominant components exposed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseCost {
    /// Total seconds.
    pub total: f64,
    /// Seconds attributable to DRAM streaming.
    pub stream: f64,
    /// Seconds attributable to ALU compute.
    pub compute: f64,
    /// Seconds attributable to unpack/bit-twiddling overhead.
    pub overhead: f64,
}

impl PhaseCost {
    fn new(stream: f64, compute: f64, overhead: f64) -> Self {
        PhaseCost {
            total: stream + compute + overhead,
            stream,
            compute,
            overhead,
        }
    }
}

/// The Table II row set for one configuration.
#[derive(Debug, Clone)]
pub struct LatencyBreakdown {
    /// Pre-fill phase (whole prompt).
    pub prefill: PhaseCost,
    /// Per-token generation latency.
    pub token_gen: PhaseCost,
    /// One-off parallel Huffman decode (zero when `huffman == false`).
    pub parallel_decode: f64,
    /// Time to first output token = decode + prefill + one token.
    pub first_token: f64,
}

impl LatencyBreakdown {
    /// Tokens/second in steady-state generation.
    pub fn tokens_per_sec(&self) -> f64 {
        1.0 / self.token_gen.total
    }
}

/// Evaluates workloads against a hardware profile.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Hardware constants.
    pub profile: Profile,
}

impl LatencyModel {
    /// Model for a profile.
    pub fn new(profile: Profile) -> Self {
        LatencyModel { profile }
    }

    /// Flops for one full forward pass over one token: ~2 FLOP per
    /// parameter (multiply + add), the standard decoder-LLM estimate.
    fn flops_per_token(&self, n_params: usize) -> f64 {
        2.0 * n_params as f64
    }

    /// Pre-fill: process `prefill_tokens` in one batched pass. Weights
    /// stream once; compute scales with tokens.
    pub fn prefill(&self, w: &Workload) -> PhaseCost {
        let stream = self.profile.stream_time(w.weight_bytes_per_pass);
        let compute = self.profile.compute_time(
            self.flops_per_token(w.n_params) * w.prefill_tokens as f64 * w.compute_scale,
        );
        let overhead = self.unpack_overhead(w);
        PhaseCost::new(stream, compute, overhead)
    }

    /// One generated token: weights stream once (GEMV), tiny compute.
    pub fn token_gen(&self, w: &Workload) -> PhaseCost {
        let stream = self.profile.stream_time(w.weight_bytes_per_pass);
        let compute = self
            .profile
            .compute_time(self.flops_per_token(w.n_params) * w.compute_scale);
        let overhead = self.unpack_overhead(w);
        PhaseCost::new(stream, compute, overhead)
    }

    fn unpack_overhead(&self, w: &Workload) -> f64 {
        // Bit-unpack cost applies to the bytes actually streamed; it is
        // what separates the paper's measured 1.32× from theoretical
        // 1.43× (§IV-D).
        w.weight_bytes_per_pass as f64 * self.profile.unpack_sec_per_byte
    }

    /// Upfront parallel Huffman decode (§III-C), once per sequence.
    pub fn parallel_decode(&self, w: &Workload) -> f64 {
        if !w.huffman {
            return 0.0;
        }
        self.profile
            .decode_time(w.n_params, w.threads, w.imbalance)
    }

    /// Full Table II breakdown.
    pub fn breakdown(&self, w: &Workload) -> LatencyBreakdown {
        let prefill = self.prefill(w);
        let token_gen = self.token_gen(w);
        let parallel_decode = self.parallel_decode(w);
        LatencyBreakdown {
            prefill,
            token_gen,
            parallel_decode,
            first_token: parallel_decode + prefill.total + token_gen.total,
        }
    }

    /// First-token latency when entropy decode **streams layer-ahead**
    /// of compute with a bounded prefetch window (`decode::stream`)
    /// instead of running as an up-front barrier.
    ///
    /// With `prefetch_layers` of `n_layers` (equal-cost) layers
    /// prefetched, compute can start once the window fills — a
    /// `prefetch/n_layers` fraction of the full decode — and the
    /// remaining decode hides behind compute. The pipeline finishes
    /// when its slower side does:
    ///
    /// ```text
    /// ttft_streaming = max(decode_total, window_fill + prefill + one_token)
    /// ```
    ///
    /// This is strictly below the eager
    /// `decode_total + prefill + one_token` whenever
    /// `prefetch_layers < n_layers` (the window fill is a proper
    /// fraction of the decode), and degrades exactly to the eager
    /// number at `prefetch_layers >= n_layers` — prefetching the whole
    /// model *is* the eager barrier.
    pub fn streaming_first_token(
        &self,
        w: &Workload,
        n_layers: usize,
        prefetch_layers: usize,
    ) -> f64 {
        let decode_total = self.parallel_decode(w);
        let compute = self.prefill(w).total + self.token_gen(w).total;
        if decode_total == 0.0 {
            return compute;
        }
        if n_layers == 0 {
            // Unknown layer structure: no overlap can be claimed, so
            // report the eager barrier rather than a fabricated win.
            return decode_total + compute;
        }
        let window = prefetch_layers.clamp(1, n_layers);
        let window_fill = decode_total * window as f64 / n_layers as f64;
        decode_total.max(window_fill + compute)
    }

    /// Eager-TTFT / streaming-TTFT for a prefetch configuration (> 1
    /// means streaming wins).
    pub fn streaming_speedup(&self, w: &Workload, n_layers: usize, prefetch_layers: usize) -> f64 {
        let eager = self.breakdown(w).first_token;
        eager / self.streaming_first_token(w, n_layers, prefetch_layers).max(1e-18)
    }

    /// Extra seconds per generated token spent **re-decoding faulted
    /// layers** when `resident_layers` of `n_layers` (equal-cost)
    /// decoded layers are **pinned** resident across passes: the
    /// per-token fault bill is `miss_fraction × full parallel decode`.
    ///
    /// `resident_layers` models a pinned (policy-optimal for cyclic
    /// scans) residency, i.e. the headroom a decode-ahead / pin-next
    /// policy recovers. A pure-LRU `crate::residency::WeightCache`
    /// under a strictly cyclic dense forward pass degenerates to
    /// **zero** effective residency whenever the budget is below the
    /// model (every access misses — see the `residency` module docs on
    /// scan behavior), so model it by passing `resident_layers = 0`;
    /// the scan-resistant segmented-LRU policy approaches
    /// `resident_layers = budget_layers - 1`. Zero cost when the
    /// workload has no Huffman stage, when the layer structure is
    /// unknown (`n_layers == 0`), or when everything is pinned.
    pub fn fault_in_per_token(
        &self,
        w: &Workload,
        n_layers: usize,
        resident_layers: usize,
    ) -> f64 {
        if !w.huffman || n_layers == 0 {
            return 0.0;
        }
        let resident = resident_layers.min(n_layers);
        let miss_fraction = (n_layers - resident) as f64 / n_layers as f64;
        self.parallel_decode(w) * miss_fraction
    }

    /// Steady-state per-token generation latency under a pinned
    /// residency: the bandwidth-bound [`LatencyModel::token_gen`] cost
    /// plus [`LatencyModel::fault_in_per_token`]. Degrades exactly to
    /// `token_gen` at full residency, and to `token_gen + full decode`
    /// per token when nothing stays resident (= the shipped LRU cache
    /// on a cyclic scan with a below-model budget).
    pub fn faulted_token_gen(&self, w: &Workload, n_layers: usize, resident_layers: usize) -> f64 {
        self.token_gen(w).total + self.fault_in_per_token(w, n_layers, resident_layers)
    }

    /// Tokens/second under a pinned residency (the
    /// `benches/residency_fault.rs` headline, modeled).
    pub fn faulted_tokens_per_sec(
        &self,
        w: &Workload,
        n_layers: usize,
        resident_layers: usize,
    ) -> f64 {
        1.0 / self.faulted_token_gen(w, n_layers, resident_layers).max(1e-18)
    }

    /// Steady-state per-token latency when **decode-ahead overlaps**
    /// fault-in with token compute (`residency::prefetch`): while layer
    /// `i`'s GEMV streams, a worker pool re-decodes layer `i+1`, so a
    /// token costs the *slower pipeline side*, not the sum:
    ///
    /// ```text
    /// overlapped = max(token_gen, fault_in_per_token)
    /// ```
    ///
    /// Degrades exactly to [`LatencyModel::token_gen`] at full
    /// residency (nothing to hide) and to the fault bill alone when the
    /// workload is decode-bound; always `<=`
    /// [`LatencyModel::faulted_token_gen`], which pays the two phases
    /// serially.
    pub fn overlapped_token_gen(
        &self,
        w: &Workload,
        n_layers: usize,
        resident_layers: usize,
    ) -> f64 {
        self.token_gen(w)
            .total
            .max(self.fault_in_per_token(w, n_layers, resident_layers))
    }

    /// Tokens/second with decode-ahead overlap (the
    /// `benches/decode_ahead.rs` headline, modeled).
    pub fn overlapped_tokens_per_sec(
        &self,
        w: &Workload,
        n_layers: usize,
        resident_layers: usize,
    ) -> f64 {
        1.0 / self.overlapped_token_gen(w, n_layers, resident_layers).max(1e-18)
    }

    /// Serial-fault / overlapped-fault latency ratio (`>= 1`): what
    /// hiding decode behind compute buys at a given residency. Peaks at
    /// 2.0 when the two pipeline sides are balanced.
    pub fn overlap_speedup(&self, w: &Workload, n_layers: usize, resident_layers: usize) -> f64 {
        self.faulted_token_gen(w, n_layers, resident_layers)
            / self.overlapped_token_gen(w, n_layers, resident_layers).max(1e-18)
    }
}

/// Build the two Table II workloads (w/o vs w/ Huffman) for a model with
/// `n_params` parameters quantized to `bits_fixed` bits and compressed to
/// `effective_bits` by Huffman coding.
///
/// Without Huffman, each weight pass streams `bits_fixed`-wide weights.
/// With Huffman the *stored/streamed* form is `effective_bits` wide and
/// the unpack happens on-chip (the paper keeps compute precision at the
/// fixed width — only memory traffic shrinks).
pub fn table2_workloads(
    n_params: usize,
    bits_fixed: u32,
    effective_bits: f64,
    prefill_tokens: usize,
    threads: usize,
    imbalance: f64,
) -> (Workload, Workload) {
    let fixed_bytes = (n_params as f64 * bits_fixed as f64 / 8.0) as usize;
    let huff_bytes = (n_params as f64 * effective_bits / 8.0) as usize;
    // int4 matmuls run ~2.8× faster than int8 on the paper's testbed
    // (prefill 9.69 s vs 27.10 s for the same prompt, Table II).
    let compute_scale = if bits_fixed <= 4 { 0.36 } else { 1.0 };
    let without = Workload {
        n_params,
        weight_bytes_per_pass: fixed_bytes,
        stored_bytes: fixed_bytes,
        prefill_tokens,
        huffman: false,
        threads,
        imbalance: 1.0,
        compute_scale,
    };
    let with = Workload {
        n_params,
        weight_bytes_per_pass: huff_bytes,
        stored_bytes: huff_bytes,
        prefill_tokens,
        huffman: true,
        threads,
        imbalance,
        compute_scale,
    };
    (without, with)
}

#[cfg(test)]
mod tests {
    use super::super::JETSON_P3450;
    use super::*;

    /// phi3-mini scale: 3.8 B params, the paper's Table II subject.
    const PHI3: usize = 3_800_000_000;

    #[test]
    fn token_gen_is_bandwidth_dominated() {
        let (w, _) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let tg = m.token_gen(&w);
        assert!(tg.stream > 5.0 * tg.compute, "stream {} compute {}", tg.stream, tg.compute);
    }

    #[test]
    fn prefill_is_compute_dominated_for_long_prompts() {
        let (w, _) = table2_workloads(PHI3, 8, 5.58, 2048, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let pf = m.prefill(&w);
        assert!(pf.compute > pf.stream, "compute {} stream {}", pf.compute, pf.stream);
    }

    #[test]
    fn huffman_speedup_matches_paper_uint8_shape() {
        // Paper §IV-D: uint8→5.58 bits gives theoretical 1.43×, measured
        // 1.32×. Our model must land between those (unpack overhead eats
        // part of the theoretical gain).
        let (without, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let t_without = m.token_gen(&without).total;
        let t_with = m.token_gen(&with).total;
        let speedup = t_without / t_with;
        assert!(
            speedup > 1.2 && speedup < 1.43,
            "uint8 token-gen speedup {speedup}"
        );
    }

    #[test]
    fn uint4_speedup_is_larger_than_uint8() {
        // Paper: uint4 (4→1.39 bits) speedup 2.47× > uint8's 1.32×.
        let m = LatencyModel::new(JETSON_P3450);
        let (w8, h8) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let (w4, h4) = table2_workloads(PHI3, 4, 1.39, 512, 4, 1.0);
        let s8 = m.token_gen(&w8).total / m.token_gen(&h8).total;
        let s4 = m.token_gen(&w4).total / m.token_gen(&h4).total;
        assert!(s4 > s8, "uint4 {s4} must beat uint8 {s8}");
        assert!(s4 > 2.0 && s4 < 2.9, "uint4 speedup {s4} near paper's 2.47x");
    }

    #[test]
    fn decode_is_once_per_sequence_and_amortizable() {
        // Paper §IV-C: decode (6.66 s for uint8) is a small fraction of
        // prefill+generation for realistic outputs.
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let b = m.breakdown(&with);
        assert!(b.parallel_decode > 0.0);
        // Amortized over 100 generated tokens it is a minor term.
        let total_100 = b.prefill.total + 100.0 * b.token_gen.total;
        assert!(b.parallel_decode < 0.5 * total_100);
    }

    #[test]
    fn no_huffman_means_no_decode_phase() {
        let (without, _) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        assert_eq!(m.parallel_decode(&without), 0.0);
        let b = m.breakdown(&without);
        assert_eq!(b.first_token, b.prefill.total + b.token_gen.total);
    }

    #[test]
    fn first_token_includes_all_upfront_work() {
        let (_, with) = table2_workloads(PHI3, 4, 1.39, 512, 4, 1.05);
        let m = LatencyModel::new(JETSON_P3450);
        let b = m.breakdown(&with);
        let expect = b.parallel_decode + b.prefill.total + b.token_gen.total;
        assert!((b.first_token - expect).abs() < 1e-12);
    }

    #[test]
    fn streaming_ttft_beats_eager_whenever_window_is_partial() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let eager = m.breakdown(&with).first_token;
        let n_layers = 32;
        for prefetch in [1usize, 2, 4, 8, 16, 31] {
            let streaming = m.streaming_first_token(&with, n_layers, prefetch);
            assert!(
                streaming < eager,
                "prefetch {prefetch}: streaming {streaming} !< eager {eager}"
            );
            assert!(m.streaming_speedup(&with, n_layers, prefetch) > 1.0);
        }
    }

    #[test]
    fn streaming_ttft_degrades_to_eager_at_full_window() {
        let (_, with) = table2_workloads(PHI3, 4, 1.39, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let eager = m.breakdown(&with).first_token;
        let full = m.streaming_first_token(&with, 32, 32);
        assert!((full - eager).abs() < 1e-12, "full window {full} vs eager {eager}");
        // Oversized windows clamp to the layer count.
        let over = m.streaming_first_token(&with, 32, 1000);
        assert!((over - eager).abs() < 1e-12);
        // Zero layers = unknown structure: no overlap may be claimed.
        let unknown = m.streaming_first_token(&with, 0, 4);
        assert!((unknown - eager).abs() < 1e-12);
    }

    #[test]
    fn streaming_ttft_is_monotone_in_prefetch_depth() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let mut prev = 0.0f64;
        for prefetch in 1..=32usize {
            let t = m.streaming_first_token(&with, 32, prefetch);
            assert!(t >= prev - 1e-15, "prefetch {prefetch}: {t} < {prev}");
            prev = t;
        }
    }

    #[test]
    fn no_huffman_means_streaming_equals_plain_compute() {
        let (without, _) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let b = m.breakdown(&without);
        let s = m.streaming_first_token(&without, 32, 4);
        assert!((s - b.first_token).abs() < 1e-12);
    }

    #[test]
    fn streaming_ttft_never_undercuts_either_pipeline_side() {
        // Sanity: the overlapped TTFT is bounded below by both the full
        // decode and the compute-only path.
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.1);
        let m = LatencyModel::new(JETSON_P3450);
        let decode = m.parallel_decode(&with);
        let compute = m.prefill(&with).total + m.token_gen(&with).total;
        for prefetch in [1usize, 8, 32] {
            let s = m.streaming_first_token(&with, 32, prefetch);
            assert!(s >= decode - 1e-15);
            assert!(s >= compute - 1e-15);
        }
    }

    #[test]
    fn full_residency_faults_nothing() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        assert_eq!(m.fault_in_per_token(&with, 32, 32), 0.0);
        assert_eq!(m.fault_in_per_token(&with, 32, 1000), 0.0, "clamped");
        let full = m.faulted_token_gen(&with, 32, 32);
        assert!((full - m.token_gen(&with).total).abs() < 1e-12);
    }

    #[test]
    fn zero_residency_pays_the_whole_decode_every_token() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let t = m.faulted_token_gen(&with, 32, 0);
        let want = m.token_gen(&with).total + m.parallel_decode(&with);
        assert!((t - want).abs() < 1e-9, "{t} vs {want}");
    }

    #[test]
    fn fault_cost_is_monotone_in_resident_layers() {
        let (_, with) = table2_workloads(PHI3, 4, 1.39, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let mut prev = f64::INFINITY;
        for resident in 0..=32usize {
            let t = m.faulted_token_gen(&with, 32, resident);
            assert!(t <= prev + 1e-15, "resident {resident}: {t} > {prev}");
            prev = t;
        }
        // Tokens/sec inverts and is monotone the other way.
        assert!(
            m.faulted_tokens_per_sec(&with, 32, 32) > m.faulted_tokens_per_sec(&with, 32, 8)
        );
    }

    #[test]
    fn no_huffman_or_unknown_layers_means_no_fault_cost() {
        let (without, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        assert_eq!(m.fault_in_per_token(&without, 32, 4), 0.0);
        assert_eq!(m.fault_in_per_token(&with, 0, 4), 0.0, "unknown structure");
    }

    #[test]
    fn overlap_never_exceeds_the_serial_fault_bill() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        for resident in 0..=32usize {
            let overlapped = m.overlapped_token_gen(&with, 32, resident);
            let serial = m.faulted_token_gen(&with, 32, resident);
            assert!(overlapped <= serial + 1e-15, "resident {resident}");
            // And never undercuts either pipeline side.
            assert!(overlapped >= m.token_gen(&with).total - 1e-15);
            assert!(overlapped >= m.fault_in_per_token(&with, 32, resident) - 1e-15);
            assert!(m.overlap_speedup(&with, 32, resident) >= 1.0 - 1e-12);
        }
    }

    #[test]
    fn overlap_degrades_to_plain_token_gen_at_full_residency() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let full = m.overlapped_token_gen(&with, 32, 32);
        assert!((full - m.token_gen(&with).total).abs() < 1e-12);
        assert!((m.overlap_speedup(&with, 32, 32) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn decode_bound_overlap_costs_exactly_the_decode() {
        // With nothing resident, the paper-scale fault bill dwarfs one
        // token's compute: the overlapped cost is the decode itself,
        // and the speedup approaches (compute + decode) / decode.
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let fault = m.fault_in_per_token(&with, 32, 0);
        let compute = m.token_gen(&with).total;
        assert!(fault > compute, "paper-scale decode dominates one GEMV");
        let overlapped = m.overlapped_token_gen(&with, 32, 0);
        assert!((overlapped - fault).abs() < 1e-12);
        let want = (compute + fault) / fault;
        assert!((m.overlap_speedup(&with, 32, 0) - want).abs() < 1e-9);
        // Tokens/sec improves accordingly.
        assert!(
            m.overlapped_tokens_per_sec(&with, 32, 0) > m.faulted_tokens_per_sec(&with, 32, 0)
        );
    }

    #[test]
    fn overlap_speedup_caps_at_two_and_peaks_when_balanced() {
        let (_, with) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        for resident in 0..=32usize {
            let s = m.overlap_speedup(&with, 32, resident);
            assert!(s <= 2.0 + 1e-9, "resident {resident}: speedup {s} > 2");
        }
    }

    #[test]
    fn no_huffman_means_no_overlap_effect() {
        let (without, _) = table2_workloads(PHI3, 8, 5.58, 512, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let t = m.overlapped_token_gen(&without, 32, 0);
        assert!((t - m.token_gen(&without).total).abs() < 1e-12);
        assert!((m.overlap_speedup(&without, 32, 0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tokens_per_sec_inverts_token_latency() {
        let (w, _) = table2_workloads(PHI3, 8, 5.58, 128, 4, 1.0);
        let m = LatencyModel::new(JETSON_P3450);
        let b = m.breakdown(&w);
        assert!((b.tokens_per_sec() * b.token_gen.total - 1.0).abs() < 1e-9);
    }
}
