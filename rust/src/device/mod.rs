//! Edge-device cost model (substitution for the paper's NVIDIA Jetson
//! P3450 testbed — see DESIGN.md §Substitutions).
//!
//! The paper's Table II latencies are governed by a simple physics:
//!
//! * **pre-fill** is compute-dominated (batch matmuls saturate the ALUs),
//!   with a secondary weight-streaming term;
//! * **token generation** is memory-bandwidth-dominated — every generated
//!   token must stream the *entire* weight set once (GEMV), so latency ≈
//!   `weight_bytes / DRAM_bandwidth` plus a small unpack overhead;
//! * **parallel Huffman decode** runs once per sequence on the CPU cores.
//!
//! [`Profile`] captures the hardware constants; [`LatencyModel`] turns a
//! workload description into the Table II rows. Byte counts and decoder
//! throughput come from *measurements* of the real pipeline; only the
//! DRAM streaming and ALU terms are modeled.

mod latency;

pub use latency::{table2_workloads, LatencyBreakdown, LatencyModel, PhaseCost, Workload};

/// Hardware constants of an edge target.
#[derive(Debug, Clone, PartialEq)]
pub struct Profile {
    /// Human-readable name.
    pub name: &'static str,
    /// DRAM bandwidth, bytes/second.
    pub dram_bytes_per_sec: f64,
    /// CPU core count (decode threads).
    pub cpu_cores: usize,
    /// CPU clock, Hz.
    pub cpu_hz: f64,
    /// Accelerator compute throughput for dense matmul, FLOP/s.
    /// (Jetson P3450: 128-core Maxwell @ ~921 MHz ≈ 236 GFLOP/s fp32 FMA.)
    pub accel_flops: f64,
    /// Shared L2 cache size in bytes (the Huffman LUT must fit here).
    pub l2_bytes: usize,
    /// Huffman decode throughput per core, symbols/second. Calibrated:
    /// the paper decodes 3.8e9 symbols in 6.66 s on 4 cores (uint8) →
    /// ≈143 M sym/s/core; our LUT decoder on a modern x86 core measures
    /// in the same order. Overridable via [`Profile::with_decode_rate`].
    pub decode_syms_per_sec_per_core: f64,
    /// Fraction of peak DRAM bandwidth achievable by streaming reads
    /// (LPDDR4 on Jetson sustains ~70–80% of nominal).
    pub dram_efficiency: f64,
    /// Per-byte cost (seconds) of unpacking non-byte-aligned weights on
    /// the accelerator — the paper's "bit-packing overheads" that explain
    /// measured 1.32× vs theoretical 1.43×.
    pub unpack_sec_per_byte: f64,
}

/// NVIDIA Jetson Nano P3450 (the paper's testbed): quad Cortex-A57 @
/// 1.43 GHz, 4 GB LPDDR4 @ 25.6 GB/s, 2 MB shared L2, 128-core Maxwell.
pub const JETSON_P3450: Profile = Profile {
    name: "NVIDIA Jetson P3450",
    dram_bytes_per_sec: 25.6e9,
    cpu_cores: 4,
    cpu_hz: 1.43e9,
    accel_flops: 236.0e9,
    l2_bytes: 2 * 1024 * 1024,
    // Calibrated to Table II: 3.8e9 params / (6.66 s × 4 cores).
    decode_syms_per_sec_per_core: 143.0e6,
    dram_efficiency: 0.75,
    // Calibrated to Table II's uint8 gap: theoretical 1.43× vs measured
    // 1.32× on a 3.8 GB model at 0.083 s/token.
    unpack_sec_per_byte: 2.4e-12,
};

/// A generic laptop/desktop-class host (used when benches report both
/// modeled-Jetson and modeled-host numbers).
pub const GENERIC_HOST: Profile = Profile {
    name: "generic x86 host",
    dram_bytes_per_sec: 40.0e9,
    cpu_cores: 8,
    cpu_hz: 3.0e9,
    accel_flops: 500.0e9,
    l2_bytes: 8 * 1024 * 1024,
    decode_syms_per_sec_per_core: 300.0e6,
    dram_efficiency: 0.8,
    unpack_sec_per_byte: 1.0e-12,
};

impl Profile {
    /// Override the decode rate with a *measured* value (benches measure
    /// the real decoder on the build host, then scale by clock ratio).
    pub fn with_decode_rate(mut self, syms_per_sec_per_core: f64) -> Self {
        self.decode_syms_per_sec_per_core = syms_per_sec_per_core;
        self
    }

    /// Effective (sustained) DRAM bandwidth in bytes/sec.
    pub fn sustained_dram(&self) -> f64 {
        self.dram_bytes_per_sec * self.dram_efficiency
    }

    /// Time to stream `bytes` from DRAM once.
    pub fn stream_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.sustained_dram()
    }

    /// Time to execute `flops` of dense matmul on the accelerator.
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.accel_flops
    }

    /// Time for `threads` cores to decode `symbols` Huffman symbols,
    /// given a load-balance factor (`imbalance ≥ 1`, 1 = perfect).
    pub fn decode_time(&self, symbols: usize, threads: usize, imbalance: f64) -> f64 {
        let threads = threads.min(self.cpu_cores).max(1);
        let per_core = symbols as f64 / threads as f64;
        per_core * imbalance / self.decode_syms_per_sec_per_core
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jetson_constants_match_paper_spec() {
        assert_eq!(JETSON_P3450.cpu_cores, 4);
        assert!((JETSON_P3450.dram_bytes_per_sec - 25.6e9).abs() < 1.0);
        assert_eq!(JETSON_P3450.l2_bytes, 2 * 1024 * 1024);
    }

    #[test]
    fn stream_time_scales_linearly() {
        let p = &JETSON_P3450;
        let t1 = p.stream_time(1_000_000_000);
        let t2 = p.stream_time(2_000_000_000);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_time_matches_table2_calibration() {
        // 3.8 B uint8 symbols on 4 threads should land near the paper's
        // 6.66 s (that's how the rate constant was derived).
        let t = JETSON_P3450.decode_time(3_800_000_000, 4, 1.0);
        assert!((t - 6.66).abs() < 0.2, "decode time {t}");
    }

    #[test]
    fn decode_threads_capped_at_cores() {
        let t4 = JETSON_P3450.decode_time(1_000_000, 4, 1.0);
        let t16 = JETSON_P3450.decode_time(1_000_000, 16, 1.0);
        assert_eq!(t4, t16);
    }

    #[test]
    fn imbalance_inflates_decode_time() {
        let t1 = JETSON_P3450.decode_time(1_000_000, 4, 1.0);
        let t2 = JETSON_P3450.decode_time(1_000_000, 4, 1.3);
        assert!(t2 > t1);
    }
}
