//! Table-driven asymmetric numeral system (tANS) entropy codec (L2).
//!
//! The second codec arm next to [`crate::huffman`]: where Huffman
//! charges an integer number of bits per symbol, tANS spreads symbols
//! over a `2^12`-state machine and charges fractional bits, closing
//! most of the gap to the Shannon bound on the skewed post-quantization
//! distributions EntroLLM lives on (PAPERS.md: "Approaching Shannon
//! Bound with Lossless LLM Weight Compression"). Same canonical-table
//! discipline as `huffman::code`: the container serializes only the
//! normalized slot counts ([`AnsTable::to_bytes`], 512 bytes) and every
//! reader derives identical spread/encode/decode tables with
//! integer-only rules.
//!
//! Segment/tile streams are MSB-first and byte-aligned like the
//! Huffman ones, carry a 12-bit final-state header, and are padded to
//! a one-bit-per-symbol floor so the ELM container's allocation-bomb
//! bound (`n_symbols ≤ 8 × encoded_len`) holds for every codec — see
//! docs/FORMAT.md §v3.
//!
//! ```
//! use entrollm::ans::{encode_with_own_table, Decoder};
//!
//! let symbols = vec![7u8, 7, 7, 3, 7, 7, 1, 7];
//! let (table, encoded) = encode_with_own_table(&symbols).unwrap();
//! let decoded = Decoder::new(&table).unwrap().decode(&encoded, symbols.len()).unwrap();
//! assert_eq!(decoded, symbols);
//! ```

pub mod code;
pub mod decoder;
pub mod encoder;

pub use code::{AnsTable, ALPHABET, SERIALIZED_BYTES, TABLE_LOG, TABLE_SIZE};
pub use decoder::Decoder;
pub use encoder::{min_stream_bytes, Encoder};

use crate::huffman::FreqTable;
use crate::Result;

/// Build a table from the symbols' own frequencies and encode them —
/// the tANS twin of [`crate::huffman::encode_with_own_code`].
pub fn encode_with_own_table(symbols: &[u8]) -> Result<(AnsTable, Vec<u8>)> {
    let table = AnsTable::build(&FreqTable::from_symbols(symbols))?;
    let encoded = Encoder::new(&table).encode_to_vec(symbols)?;
    Ok((table, encoded))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, gen};

    fn roundtrip(symbols: &[u8]) -> Result<Vec<u8>> {
        let (table, bytes) = encode_with_own_table(symbols)?;
        Decoder::new(&table)?.decode(&bytes, symbols.len())
    }

    /// Property: roundtrip across the generator's distribution mix
    /// (uniform-256, uniform-16, heavy-mode, discretized Gaussian),
    /// mirroring the huffman prop suite.
    #[test]
    fn prop_roundtrip_random_distributions() {
        forall(
            0xA45_0001,
            60,
            |rng| gen::symbols(rng, 5000),
            |syms| match roundtrip(syms) {
                Ok(out) if out == *syms => Ok(()),
                Ok(_) => Err("decoded symbols differ".into()),
                Err(e) => Err(format!("roundtrip failed: {e}")),
            },
        );
    }

    /// Adversarial distribution: a single symbol. The table gives it
    /// every state, each step costs 0 bits, and the stream collapses
    /// to the state header plus the one-bit-per-symbol floor pad.
    #[test]
    fn prop_single_symbol_degenerate_table() {
        for n in [1usize, 7, 8, 9, 4096] {
            let syms = vec![200u8; n];
            let (table, bytes) = encode_with_own_table(&syms).unwrap();
            assert_eq!(table.norm()[200], TABLE_SIZE as u16);
            assert_eq!(bytes.len(), 2usize.max(n.div_ceil(8)));
            assert_eq!(Decoder::new(&table).unwrap().decode(&bytes, n).unwrap(), syms);
        }
    }

    /// Adversarial distribution: two symbols, heavily skewed — the
    /// case where Huffman is pinned at 1 bit/symbol but tANS charges
    /// the true fractional entropy (≈0.08 bits at 1%). The floor pad
    /// keeps the stream at exactly n/8 bytes, still 8× under Huffman's
    /// best case for 8-bit symbols… and equal to it for this one.
    #[test]
    fn prop_two_symbol_heavy_skew() {
        let mut rng = crate::rng::Rng::new(0xA45_0002);
        let n = 50_000usize;
        let syms: Vec<u8> = (0..n)
            .map(|_| if rng.below(100) == 0 { 9 } else { 4 })
            .collect();
        let (table, bytes) = encode_with_own_table(&syms).unwrap();
        // Raw tANS cost is ~entropy (≈0.08 bits/sym) — far below the
        // 1-bit floor, so the pad dominates.
        assert_eq!(bytes.len(), n.div_ceil(8));
        assert_eq!(
            Decoder::new(&table).unwrap().decode(&bytes, n).unwrap(),
            syms
        );
    }

    /// Adversarial distribution: uniform over all 256 symbols — the
    /// incompressible end. tANS must stay within rounding of 8
    /// bits/symbol and still roundtrip.
    #[test]
    fn prop_uniform_256_symbols() {
        let mut rng = crate::rng::Rng::new(0xA45_0003);
        let n = 40_000usize;
        let syms: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let (table, bytes) = encode_with_own_table(&syms).unwrap();
        let bits_per_sym = 8.0 * bytes.len() as f64 / n as f64;
        assert!(
            (7.9..8.2).contains(&bits_per_sym),
            "uniform-256 must cost ~8 bits/symbol, got {bits_per_sym:.3}"
        );
        assert_eq!(
            Decoder::new(&table).unwrap().decode(&bytes, n).unwrap(),
            syms
        );
    }

    /// Adversarial distribution: the empty segment. No table can be
    /// built from zero symbols (same contract as huffman), but a
    /// decoder built from any table must accept the 0-symbol/0-byte
    /// stream — that is what the container's empty tiles decode.
    #[test]
    fn prop_empty_segment() {
        assert!(encode_with_own_table(&[]).is_err());
        let (table, _) = encode_with_own_table(&[1, 2, 3]).unwrap();
        let enc = Encoder::new(&table);
        assert!(enc.encode_to_vec(&[]).unwrap().is_empty());
        assert!(Decoder::new(&table).unwrap().decode(&[], 0).unwrap().is_empty());
    }

    /// Adversarial frequencies: counts near u64 saturation. The
    /// normalization must not overflow (u128 internally) and must
    /// still hand every present symbol at least one slot.
    #[test]
    fn prop_max_frequency_saturation() {
        let mut saturated = FreqTable::new();
        saturated.add_count(0, u64::MAX / 2);
        saturated.add_count(1, u64::MAX / 2);
        saturated.add_count(2, 1);
        let table = AnsTable::build(&saturated).unwrap();
        assert_eq!(
            table.norm().iter().map(|&n| n as u64).sum::<u64>(),
            TABLE_SIZE as u64
        );
        assert!(table.norm()[2] >= 1, "rare symbol must stay encodable");
        // And the table actually codes: mostly-heavy symbols + rares.
        let syms: Vec<u8> = (0..1000).map(|i| if i % 300 == 0 { 2 } else { (i % 2) as u8 }).collect();
        let bytes = Encoder::new(&table).encode_to_vec(&syms).unwrap();
        assert_eq!(
            Decoder::new(&table).unwrap().decode(&bytes, syms.len()).unwrap(),
            syms
        );
    }

    /// Table serialization roundtrip: counts → bytes → counts must be
    /// the identity, and the rebuilt table must be indistinguishable
    /// (same spread, same streams) — the huffman
    /// `spec_survives_length_serialization` property for tANS.
    #[test]
    fn prop_table_survives_count_serialization() {
        forall(
            0xA45_0004,
            40,
            |rng| gen::symbols(rng, 3000),
            |syms| {
                let (table, bytes) = encode_with_own_table(syms).map_err(|e| e.to_string())?;
                let rebuilt = AnsTable::from_bytes(&table.to_bytes()).map_err(|e| e.to_string())?;
                if rebuilt != table {
                    return Err("rebuilt table differs from original".into());
                }
                let re_bytes = Encoder::new(&rebuilt)
                    .encode_to_vec(syms)
                    .map_err(|e| e.to_string())?;
                if re_bytes != bytes {
                    return Err("rebuilt table encodes a different stream".into());
                }
                let out = Decoder::new(&rebuilt)
                    .and_then(|d| d.decode(&bytes, syms.len()))
                    .map_err(|e| e.to_string())?;
                if out != *syms {
                    return Err("rebuilt table decodes to different symbols".into());
                }
                Ok(())
            },
        );
    }

    /// On the fig4-style skewed (discretized Gaussian) distributions,
    /// tANS encoded size must be ≤ Huffman's — the whole point of the
    /// codec arm (both sides measured without container overheads).
    #[test]
    fn ans_beats_or_matches_huffman_on_skewed_distributions() {
        let mut rng = crate::rng::Rng::new(0xA45_0005);
        for (mu, sigma) in [(128.0, 6.0), (128.0, 24.0), (8.0, 2.0)] {
            let n = 60_000usize;
            let syms: Vec<u8> = (0..n)
                .map(|_| rng.gaussian_f32(mu, sigma).round().clamp(0.0, 255.0) as u8)
                .collect();
            let (_, ans_bytes) = encode_with_own_table(&syms).unwrap();
            let (_, huff_bytes) = crate::huffman::encode_with_own_code(&syms).unwrap();
            assert!(
                ans_bytes.len() <= huff_bytes.len(),
                "tANS ({}) must not lose to Huffman ({}) on N({mu},{sigma})",
                ans_bytes.len(),
                huff_bytes.len()
            );
        }
    }
}
