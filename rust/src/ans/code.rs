//! Normalized frequency tables for the tANS codec — the `CodeSpec`
//! analogue (L2, format layer).
//!
//! A [`AnsTable`] is the complete, canonical description of a tANS
//! code: 256 per-symbol slot counts that sum to exactly
//! `TABLE_SIZE = 2^TABLE_LOG`. Everything else — the deterministic
//! symbol spread, the encode/decode state tables — is *derived* from
//! those counts by integer-only rules, so a container only ever
//! serializes the counts (512 bytes, `u16` LE per symbol) and any
//! conforming reader rebuilds bit-identical tables. This mirrors the
//! canonical-Huffman discipline in [`crate::huffman::code`]: the wire
//! format carries the minimum, the construction is normative.

use crate::huffman::FreqTable;
use crate::{Error, Result};

/// Symbol alphabet (quantized weights are bytes; uint4 uses `0..=15`).
pub const ALPHABET: usize = 256;

/// log2 of the state-table size. 12 bits quantizes symbol
/// probabilities to 1/4096 — within ~0.001 bits/symbol of entropy on
/// the paper's distributions — while the decode table (4096 × 4 B)
/// stays L1/L2-resident next to the Huffman LUT.
pub const TABLE_LOG: u8 = 12;

/// Number of tANS states (and slots in the spread): `2^TABLE_LOG`.
pub const TABLE_SIZE: usize = 1 << TABLE_LOG;

/// Serialized size of a table: one `u16` (LE) slot count per symbol.
pub const SERIALIZED_BYTES: usize = ALPHABET * 2;

/// A canonical tANS table: normalized slot counts plus the derived
/// spread. Construction is integer-only and deterministic, so two
/// builds from the same counts are identical on every platform.
#[derive(Debug, Clone, PartialEq)]
pub struct AnsTable {
    /// Per-symbol slot counts, summing to exactly [`TABLE_SIZE`].
    /// A zero count means "symbol does not occur" (unencodable).
    norm: [u16; ALPHABET],
    /// `cumul[s]` = total slots of all symbols `< s`; `cumul[256]` =
    /// [`TABLE_SIZE`]. Indexes the per-symbol region of the encode
    /// state table.
    cumul: [u32; ALPHABET + 1],
    /// The symbol occupying each of the [`TABLE_SIZE`] state slots,
    /// in spread order (see [`spread_symbols`]).
    spread: Vec<u8>,
}

/// The deterministic spread: symbol `s` occupies `norm[s]` slots,
/// visited in symbol order, each placed `STEP` slots after the last
/// (mod [`TABLE_SIZE`]). `STEP = L/2 + L/8 + 3` is odd, hence coprime
/// with the power-of-two table size, so the walk visits every slot
/// exactly once — the standard FSE spread, chosen here for the same
/// reason: it scatters each symbol's slots roughly uniformly, which
/// is what keeps the per-state bit counts near `-log2(p)`.
fn spread_symbols(norm: &[u16; ALPHABET]) -> Vec<u8> {
    const STEP: usize = (TABLE_SIZE >> 1) + (TABLE_SIZE >> 3) + 3;
    let mut spread = vec![0u8; TABLE_SIZE];
    let mut pos = 0usize;
    for (sym, &n) in norm.iter().enumerate() {
        for _ in 0..n {
            spread[pos] = sym as u8;
            pos = (pos + STEP) & (TABLE_SIZE - 1);
        }
    }
    debug_assert_eq!(pos, 0, "coprime step must close its cycle");
    spread
}

impl AnsTable {
    /// Normalize raw symbol frequencies to slot counts summing to
    /// [`TABLE_SIZE`] and build the canonical table.
    ///
    /// Integer-only largest-remainder style normalization: each
    /// present symbol gets `max(1, count·L/total)` slots (present
    /// symbols must stay encodable), then the residual is settled
    /// deterministically — deficits go to the most frequent symbol
    /// (smallest index on ties), excess is shaved off the currently
    /// largest allocation (again smallest index on ties), never below
    /// one slot.
    pub fn build(freq: &FreqTable) -> Result<Self> {
        if freq.distinct() == 0 {
            return Err(Error::InvalidArg(
                "cannot build a tANS table from an empty frequency table".into(),
            ));
        }
        // u128 throughout: counts are u64 and the scale multiply
        // would overflow u64 near saturation.
        let total: u128 = freq.counts().iter().map(|&c| c as u128).sum();
        let mut norm = [0u16; ALPHABET];
        for (sym, &count) in freq.counts().iter().enumerate() {
            if count == 0 {
                continue;
            }
            let share = (count as u128 * TABLE_SIZE as u128) / total;
            norm[sym] = (share as u64).clamp(1, TABLE_SIZE as u64) as u16;
        }
        let mut sum: i64 = norm.iter().map(|&n| n as i64).sum();
        // Deficit: award everything to the most frequent symbol — the
        // cheap symbol absorbs rounding with the least rate damage.
        if sum < TABLE_SIZE as i64 {
            let richest = (0..ALPHABET)
                .filter(|&s| freq.count(s as u8) > 0)
                .max_by_key(|&s| (freq.count(s as u8), std::cmp::Reverse(s)))
                .expect("distinct > 0");
            norm[richest] += (TABLE_SIZE as i64 - sum) as u16;
            sum = TABLE_SIZE as i64;
        }
        // Excess (the max(1,·) floors overshot): shave the largest
        // allocation one slot at a time. Terminates because
        // sum > L ≥ 256 ≥ #present implies some norm > 1.
        while sum > TABLE_SIZE as i64 {
            let fattest = (0..ALPHABET)
                .filter(|&s| norm[s] > 1)
                .max_by_key(|&s| (norm[s], std::cmp::Reverse(s)))
                .expect("sum > TABLE_SIZE implies a shrinkable symbol");
            norm[fattest] -= 1;
            sum -= 1;
        }
        Self::from_counts(&norm)
    }

    /// Rebuild a table from (de)serialized slot counts, validating the
    /// canonical invariant: counts sum to exactly [`TABLE_SIZE`].
    /// This is the reader-side entry point — the container stores only
    /// these counts.
    pub fn from_counts(norm: &[u16; ALPHABET]) -> Result<Self> {
        let sum: u64 = norm.iter().map(|&n| n as u64).sum();
        if sum != TABLE_SIZE as u64 {
            return Err(Error::Format(format!(
                "tANS slot counts must sum to {TABLE_SIZE}, got {sum}"
            )));
        }
        let mut cumul = [0u32; ALPHABET + 1];
        for s in 0..ALPHABET {
            cumul[s + 1] = cumul[s] + norm[s] as u32;
        }
        Ok(AnsTable {
            norm: *norm,
            cumul,
            spread: spread_symbols(norm),
        })
    }

    /// Per-symbol normalized slot counts (sum = [`TABLE_SIZE`]).
    pub fn norm(&self) -> &[u16; ALPHABET] {
        &self.norm
    }

    /// Slots of all symbols below `s` (encode-table region offsets).
    pub fn cumul(&self) -> &[u32; ALPHABET + 1] {
        &self.cumul
    }

    /// The symbol occupying each state slot, in spread order.
    pub fn spread(&self) -> &[u8] {
        &self.spread
    }

    /// Serialize the canonical counts: 256 × `u16` little-endian.
    pub fn to_bytes(&self) -> [u8; SERIALIZED_BYTES] {
        let mut out = [0u8; SERIALIZED_BYTES];
        for (s, &n) in self.norm.iter().enumerate() {
            out[2 * s..2 * s + 2].copy_from_slice(&n.to_le_bytes());
        }
        out
    }

    /// Deserialize [`to_bytes`](Self::to_bytes) output (container
    /// reader path). All-zero bytes are NOT special here — that is
    /// decided by the container rules (see `store::read_manifest`).
    pub fn from_bytes(bytes: &[u8; SERIALIZED_BYTES]) -> Result<Self> {
        let mut norm = [0u16; ALPHABET];
        for (s, slot) in norm.iter_mut().enumerate() {
            *slot = u16::from_le_bytes([bytes[2 * s], bytes[2 * s + 1]]);
        }
        Self::from_counts(&norm)
    }

    /// Mean code length in bits/symbol this table achieves on the
    /// given raw frequencies (exact expected cost of the quantized
    /// probabilities, ignoring the constant 12-bit stream header):
    /// `Σ p_s · (TABLE_LOG − log2(norm_s))`. Diagnostic only — the
    /// table build itself never touches floating point.
    pub fn expected_bits(&self, freq: &FreqTable) -> f64 {
        let total: u128 = freq.counts().iter().map(|&c| c as u128).sum();
        if total == 0 {
            return 0.0;
        }
        let mut bits = 0.0f64;
        for (s, &count) in freq.counts().iter().enumerate() {
            if count == 0 || self.norm[s] == 0 {
                continue;
            }
            let p = count as f64 / total as f64;
            bits += p * (TABLE_LOG as f64 - (self.norm[s] as f64).log2());
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_sums_to_table_size_and_keeps_symbols_encodable() {
        let mut freq = FreqTable::new();
        // 200 present symbols with wildly different counts, including
        // ones far below 1/TABLE_SIZE probability (must still get a slot).
        for s in 0..200u8 {
            for _ in 0..(1 + (s as usize % 7) * 1000) {
                freq.add_symbols(&[s]);
            }
        }
        let t = AnsTable::build(&freq).unwrap();
        assert_eq!(t.norm().iter().map(|&n| n as u64).sum::<u64>(), TABLE_SIZE as u64);
        for s in 0..200u8 {
            assert!(t.norm()[s as usize] >= 1, "present symbol {s} lost its slot");
        }
        for s in 200..=255u8 {
            assert_eq!(t.norm()[s as usize], 0, "absent symbol {s} must stay zero");
        }
    }

    #[test]
    fn spread_covers_every_state_exactly_once() {
        let mut freq = FreqTable::new();
        freq.add_symbols(&[0, 0, 0, 1, 1, 2]);
        let t = AnsTable::build(&freq).unwrap();
        let mut per_sym = [0u32; ALPHABET];
        for &s in t.spread() {
            per_sym[s as usize] += 1;
        }
        for s in 0..ALPHABET {
            assert_eq!(per_sym[s], t.norm()[s] as u32, "spread slots must match norm[{s}]");
        }
    }

    #[test]
    fn single_symbol_table_owns_the_whole_state_space() {
        let mut freq = FreqTable::new();
        freq.add_symbols(&[42; 10]);
        let t = AnsTable::build(&freq).unwrap();
        assert_eq!(t.norm()[42], TABLE_SIZE as u16);
        assert!(t.spread().iter().all(|&s| s == 42));
    }

    #[test]
    fn from_counts_rejects_bad_sums() {
        let mut norm = [0u16; ALPHABET];
        norm[0] = TABLE_SIZE as u16 - 1;
        assert!(AnsTable::from_counts(&norm).is_err());
        norm[0] = TABLE_SIZE as u16;
        norm[1] = 1;
        assert!(AnsTable::from_counts(&norm).is_err());
        assert!(AnsTable::from_counts(&[0u16; ALPHABET]).is_err());
    }

    #[test]
    fn build_is_deterministic() {
        let mut freq = FreqTable::new();
        let mut rng = crate::rng::Rng::new(7);
        let syms: Vec<u8> = (0..5000).map(|_| rng.below(256) as u8).collect();
        freq.add_symbols(&syms);
        let a = AnsTable::build(&freq).unwrap();
        let b = AnsTable::build(&freq).unwrap();
        assert_eq!(a, b);
    }
}
