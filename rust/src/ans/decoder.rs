//! tANS decoding (L2) — the table-driven hot path and its bit-serial
//! oracle.
//!
//! The decoder walks the state chain forward: read the 12-bit final
//! state the encoder left at the front, then per symbol look up
//! `(symbol, nbits, base)` for the current state, emit the symbol, and
//! absorb `nbits` fresh bits into the next state. Two integrity checks
//! make corrupt streams loud rather than silently plausible:
//!
//! * **state return** — the chain must end on the encoder's fixed
//!   start state (`x = L`); a corrupted stream that still produces
//!   `n` symbols almost never lands there;
//! * **exact length** — the stream must be exactly
//!   `max(ceil(consumed_bits/8), ceil(n/8))` bytes: byte-alignment
//!   padding plus the codec-independent one-bit-per-symbol floor
//!   (see [`super::encoder::min_stream_bytes`]), nothing more.

use super::code::{AnsTable, ALPHABET, TABLE_LOG, TABLE_SIZE};
use super::encoder::min_stream_bytes;
use crate::bitio::BitReader;
use crate::{Error, Result};

/// One decode-table entry: what state `st` emits and how it advances.
#[derive(Debug, Clone, Copy)]
struct Entry {
    /// Symbol emitted from this state.
    symbol: u8,
    /// Fresh bits to absorb: `TABLE_LOG - floor(log2(slot))`.
    nbits: u8,
    /// `(slot << nbits) - TABLE_SIZE`: next state before the bits.
    base: u16,
}

/// Table-driven tANS decoder (one entry per state, 4 bytes each —
/// 16 KiB, L1-resident on the target edge SoCs).
#[derive(Debug, Clone)]
pub struct Decoder {
    entries: Vec<Entry>,
    /// Kept for the bit-serial oracle, which must not share the
    /// packed entries it is checking.
    norm: [u16; ALPHABET],
    spread: Vec<u8>,
}

impl Decoder {
    /// Precompute the per-state decode entries from a canonical table.
    pub fn new(table: &AnsTable) -> Result<Self> {
        let mut next = [0u32; ALPHABET];
        for (s, slot) in next.iter_mut().enumerate() {
            *slot = table.norm()[s] as u32;
        }
        let mut entries = Vec::with_capacity(TABLE_SIZE);
        for &sym in table.spread() {
            let slot = next[sym as usize];
            next[sym as usize] += 1;
            // slot ∈ [norm, 2·norm) and norm ≥ 1, so ilog2 is defined
            // and (slot << nbits) ∈ [L, 2L).
            let nbits = TABLE_LOG - slot.ilog2() as u8;
            entries.push(Entry {
                symbol: sym,
                nbits,
                base: ((slot << nbits) - TABLE_SIZE as u32) as u16,
            });
        }
        Ok(Decoder {
            entries,
            norm: *table.norm(),
            spread: table.spread().to_vec(),
        })
    }

    /// Decode exactly `out.len()` symbols from `bytes` — the hot path.
    ///
    /// Rejects truncation, trailing garbage (beyond alignment padding
    /// and the one-bit-per-symbol floor), and any stream whose state
    /// chain does not return to the encoder's start state.
    pub fn decode_into(&self, bytes: &[u8], out: &mut [u8]) -> Result<()> {
        if out.is_empty() {
            return if bytes.is_empty() {
                Ok(())
            } else {
                Err(Error::Format(format!(
                    "empty tANS segment carries {} bytes",
                    bytes.len()
                )))
            };
        }
        let mut r = BitReader::new(bytes);
        let mut st = r
            .read_bits(TABLE_LOG)
            .map_err(|_| Error::Format("tANS stream shorter than its state header".into()))?
            as usize;
        for slot in out.iter_mut() {
            let e = self.entries[st];
            *slot = e.symbol;
            let bits = r.read_bits(e.nbits).map_err(|_| {
                Error::Format("tANS bitstream exhausted before all symbols decoded".into())
            })?;
            st = e.base as usize + bits as usize;
        }
        if st != 0 {
            return Err(Error::Format(format!(
                "tANS state chain ended at {st}, not the encoder start state"
            )));
        }
        let expected = r.bit_pos().div_ceil(8).max(min_stream_bytes(out.len()));
        if bytes.len() != expected {
            return Err(Error::Format(format!(
                "tANS stream is {} bytes, expected exactly {expected}",
                bytes.len()
            )));
        }
        Ok(())
    }

    /// Allocate-and-decode convenience over [`decode_into`](Self::decode_into).
    pub fn decode(&self, bytes: &[u8], n_symbols: usize) -> Result<Vec<u8>> {
        let mut out = vec![0u8; n_symbols];
        self.decode_into(bytes, &mut out)?;
        Ok(out)
    }

    /// Naive bit-serial oracle: decodes with only the canonical table
    /// definition (spread + norm) — no packed entries, no bulk bit
    /// reads, every derived quantity recomputed per symbol from first
    /// principles. Slow by design; exists to differentially check
    /// [`decode_into`](Self::decode_into), so the two share no
    /// shortcuts that could hide a common bug.
    pub fn decode_bit_serial(&self, bytes: &[u8], n_symbols: usize) -> Result<Vec<u8>> {
        if n_symbols == 0 {
            return if bytes.is_empty() {
                Ok(Vec::new())
            } else {
                Err(Error::Format("empty tANS segment carries bytes".into()))
            };
        }
        let mut r = BitReader::new(bytes);
        let mut st = 0usize;
        for _ in 0..TABLE_LOG {
            st = (st << 1) | r.read_bit().map_err(|_| {
                Error::Format("tANS stream shorter than its state header".into())
            })? as usize;
        }
        let mut out = Vec::with_capacity(n_symbols);
        let mut consumed = TABLE_LOG as usize;
        for _ in 0..n_symbols {
            let sym = self.spread[st];
            out.push(sym);
            // This state's slot value: norm[sym] plus how many earlier
            // states the spread gave to the same symbol.
            let rank = self.spread[..st].iter().filter(|&&s| s == sym).count();
            let mut slot = self.norm[sym as usize] as usize + rank;
            // Shift the slot back up into [L, 2L) one bit at a time.
            let mut st_next = slot;
            let mut nbits = 0usize;
            while st_next < TABLE_SIZE {
                let bit = r.read_bit().map_err(|_| {
                    Error::Format("tANS bitstream exhausted before all symbols decoded".into())
                })? as usize;
                st_next = (st_next << 1) | bit;
                nbits += 1;
            }
            consumed += nbits;
            slot = st_next; // now the full next state in [L, 2L)
            st = slot - TABLE_SIZE;
        }
        if st != 0 {
            return Err(Error::Format(
                "tANS state chain ended off the encoder start state (oracle)".into(),
            ));
        }
        let expected = consumed.div_ceil(8).max(min_stream_bytes(n_symbols));
        if bytes.len() != expected {
            return Err(Error::Format(format!(
                "tANS stream is {} bytes, oracle expected exactly {expected}",
                bytes.len()
            )));
        }
        Ok(out)
    }

    /// Decode-table footprint in bytes (capacity-planning aid, mirrors
    /// `huffman::Decoder::table_bytes`).
    pub fn table_bytes(&self) -> usize {
        self.entries.len() * std::mem::size_of::<Entry>()
    }
}

#[cfg(test)]
mod tests {
    use super::super::encoder::Encoder;
    use super::*;
    use crate::huffman::FreqTable;

    fn table_for(symbols: &[u8]) -> AnsTable {
        AnsTable::build(&FreqTable::from_symbols(symbols)).unwrap()
    }

    #[test]
    fn roundtrip_gaussianish_symbols() {
        let mut rng = crate::rng::Rng::new(0x7A5);
        let syms: Vec<u8> = (0..20_000)
            .map(|_| (rng.below(8) + rng.below(8) + rng.below(8)) as u8)
            .collect();
        let table = table_for(&syms);
        let bytes = Encoder::new(&table).encode_to_vec(&syms).unwrap();
        let dec = Decoder::new(&table).unwrap();
        assert_eq!(dec.decode(&bytes, syms.len()).unwrap(), syms);
        assert_eq!(dec.decode_bit_serial(&bytes, syms.len()).unwrap(), syms);
    }

    #[test]
    fn truncated_stream_errors() {
        let syms: Vec<u8> = (0..100u8).cycle().take(10_000).collect();
        let table = table_for(&syms);
        let bytes = Encoder::new(&table).encode_to_vec(&syms).unwrap();
        let dec = Decoder::new(&table).unwrap();
        assert!(dec.decode(&bytes[..bytes.len() / 2], syms.len()).is_err());
    }

    #[test]
    fn excess_trailing_bytes_error() {
        let syms = vec![1u8, 2, 3, 1, 2, 3, 2, 2];
        let table = table_for(&syms);
        let mut bytes = Encoder::new(&table).encode_to_vec(&syms).unwrap();
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let dec = Decoder::new(&table).unwrap();
        assert!(dec.decode(&bytes, syms.len()).is_err());
    }

    #[test]
    fn empty_segment_decodes_from_empty_stream_only() {
        let table = table_for(&[5, 5, 6]);
        let dec = Decoder::new(&table).unwrap();
        assert!(dec.decode(&[], 0).unwrap().is_empty());
        assert!(dec.decode_bit_serial(&[], 0).unwrap().is_empty());
        assert!(dec.decode(&[0], 0).is_err());
        assert!(dec.decode_bit_serial(&[0], 0).is_err());
    }

    #[test]
    fn table_bytes_bounded_by_l1() {
        let table = table_for(&[1, 2, 3, 4, 5]);
        let dec = Decoder::new(&table).unwrap();
        assert!(dec.table_bytes() <= 32 * 1024, "decode table must stay cache-resident");
    }

    /// Seeded differential fuzz for the tANS arm: the table-driven hot
    /// path ([`Decoder::decode_into`]) against the bit-serial oracle
    /// ([`Decoder::decode_bit_serial`]) on valid, truncated, and
    /// bit-flipped streams — the PR 6 Huffman harness applied to the
    /// new codec. Both paths implement the full validation rules
    /// (state return, exact padded length) independently, so the
    /// comparison is strict: identical output or both reject.
    /// `ENTROLLM_FUZZ_CASES` bounds the case count; failures print a
    /// replay seed for [`crate::prop::forall_seeded`].
    #[test]
    fn differential_fuzz_ans_decode_into_vs_bit_serial() {
        let cases: usize = std::env::var("ENTROLLM_FUZZ_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
        crate::prop::forall(
            0xA45_0D1F,
            cases,
            |rng| {
                let syms = crate::prop::gen::symbols(rng, 1200);
                let table = table_for(&syms);
                let mut bytes = Encoder::new(&table).encode_to_vec(&syms).unwrap();
                let label = match rng.below(3) {
                    0 => "valid",
                    1 => {
                        bytes.truncate(rng.below(bytes.len() + 1));
                        "truncated"
                    }
                    _ => {
                        if bytes.is_empty() {
                            "valid"
                        } else {
                            for _ in 0..1 + rng.below(8) {
                                let i = rng.below(bytes.len());
                                bytes[i] ^= 1 << rng.below(8);
                            }
                            "bit-flipped"
                        }
                    }
                };
                (label, syms, bytes)
            },
            |(label, syms, bytes)| {
                let table = table_for(syms);
                let dec = Decoder::new(&table).unwrap();

                let mut buf = vec![0u8; syms.len()];
                let fast = dec.decode_into(bytes, &mut buf).map(|()| buf);
                let oracle = dec.decode_bit_serial(bytes, syms.len());

                match (fast, oracle) {
                    (Ok(a), Ok(b)) if a != b => {
                        Err(format!("{label}: both decoded but outputs differ"))
                    }
                    (Ok(a), Ok(_)) if *label == "valid" && a != *syms => {
                        Err(format!("{label}: decoded output differs from the encoded symbols"))
                    }
                    (Ok(_), Ok(_)) | (Err(_), Err(_)) => Ok(()),
                    (Ok(_), Err(e)) => {
                        Err(format!("{label}: table path accepted a stream the oracle rejects ({e})"))
                    }
                    (Err(e), Ok(_)) => {
                        Err(format!("{label}: table path rejected a stream the oracle accepts ({e})"))
                    }
                }
            },
        );
    }
}
