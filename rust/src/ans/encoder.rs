//! tANS encoding against an [`AnsTable`] (L2).
//!
//! ANS encodes *backward*: the encoder walks the symbols last-to-first
//! pushing bits, and the decoder pops them first-to-last. To keep the
//! container's streams forward-readable (MSB-first, like the Huffman
//! segments), the encoder buffers its per-step bit fields and writes
//! them in reverse step order behind a 12-bit final-state header — the
//! decoder then reads header, then fields, strictly left to right.
//!
//! Stream layout of one encoded tile (see docs/FORMAT.md §v3):
//!
//! ```text
//! [final_state - L : TABLE_LOG bits][field for sym 1][field for sym 2]…
//! ```
//!
//! zero-padded in the low bits of the last byte AND zero-padded up to
//! `ceil(n_symbols/8)` bytes — the uniform one-bit-per-symbol floor
//! that keeps the container's allocation-bomb bound codec-independent.

use super::code::{AnsTable, ALPHABET, TABLE_LOG, TABLE_SIZE};
use crate::bitio::BitWriter;
use crate::{Error, Result};

/// Precomputed encode tables for one [`AnsTable`].
#[derive(Debug, Clone)]
pub struct Encoder {
    norm: [u16; ALPHABET],
    cumul: [u32; ALPHABET + 1],
    /// `state_of[cumul[s] + (slot - norm[s])]` = the state index in
    /// `0..TABLE_SIZE` whose decode entry emits symbol `s` from slot
    /// value `slot ∈ [norm[s], 2·norm[s])`. Exact inverse of the
    /// decoder's state walk.
    state_of: Vec<u16>,
}

/// Minimum legal byte length of a tANS stream decoding `n` symbols:
/// the same one-bit-per-symbol floor Huffman streams satisfy
/// naturally. Encoders pad up to it; decoders use it to validate
/// stream length exactly.
pub fn min_stream_bytes(n_symbols: usize) -> usize {
    n_symbols.div_ceil(8)
}

impl Encoder {
    /// Build the encode table (the inverse of the decode state walk:
    /// scan states in order, hand each to the next slot of its spread
    /// symbol).
    pub fn new(table: &AnsTable) -> Self {
        let mut state_of = vec![0u16; TABLE_SIZE];
        let mut next = [0u32; ALPHABET];
        for (s, slot) in next.iter_mut().enumerate() {
            *slot = table.norm()[s] as u32;
        }
        for (state, &sym) in table.spread().iter().enumerate() {
            let s = sym as usize;
            let slot = next[s];
            next[s] += 1;
            state_of[(table.cumul()[s] + (slot - table.norm()[s] as u32)) as usize] =
                state as u16;
        }
        Encoder {
            norm: *table.norm(),
            cumul: *table.cumul(),
            state_of,
        }
    }

    /// Encode `symbols` into a fresh, byte-aligned stream (the layout
    /// in the module docs). Errors on any symbol with zero slots.
    /// Empty input encodes to an empty stream — the container's empty
    /// tiles stay zero bytes under every codec.
    pub fn encode_to_vec(&self, symbols: &[u8]) -> Result<Vec<u8>> {
        if symbols.is_empty() {
            return Ok(Vec::new());
        }
        // Backward pass: collect (bits, nbits) per step. x ∈ [L, 2L).
        let mut fields: Vec<(u32, u8)> = Vec::with_capacity(symbols.len());
        let mut x: u32 = TABLE_SIZE as u32;
        for &sym in symbols.iter().rev() {
            let q = self.norm[sym as usize] as u32;
            if q == 0 {
                return Err(Error::InvalidArg(format!(
                    "symbol {sym} has no tANS slots (not in the frequency table)"
                )));
            }
            // Minimal shift putting x>>nbits into [q, 2q): halving
            // from ≥2q lands ≥q, and nbits=0 is fine since x ≥ L ≥ q.
            let mut nbits = 0u8;
            while (x >> nbits) >= 2 * q {
                nbits += 1;
            }
            fields.push((x & ((1u32 << nbits) - 1), nbits));
            let slot = (x >> nbits) - q;
            x = TABLE_SIZE as u32
                + self.state_of[(self.cumul[sym as usize] + slot) as usize] as u32;
        }
        // Forward pass: final state first, then the fields reversed —
        // the decoder re-walks the chain reading left to right.
        let mut w = BitWriter::with_capacity(symbols.len() / 2 + 8);
        w.write_bits((x - TABLE_SIZE as u32) as u64, TABLE_LOG);
        for &(bits, nbits) in fields.iter().rev() {
            w.write_bits(bits as u64, nbits);
        }
        w.align_byte();
        let mut out = w.into_bytes();
        // Pad to the codec-independent one-bit-per-symbol floor.
        if out.len() < min_stream_bytes(symbols.len()) {
            out.resize(min_stream_bytes(symbols.len()), 0);
        }
        Ok(out)
    }

    /// Exact bit cost of `symbols` under this table (header included,
    /// before byte alignment and the min-length pad).
    pub fn bit_len(&self, symbols: &[u8]) -> Result<usize> {
        if symbols.is_empty() {
            return Ok(0);
        }
        let mut bits = TABLE_LOG as usize;
        let mut x: u32 = TABLE_SIZE as u32;
        for &sym in symbols.iter().rev() {
            let q = self.norm[sym as usize] as u32;
            if q == 0 {
                return Err(Error::InvalidArg(format!(
                    "symbol {sym} has no tANS slots (not in the frequency table)"
                )));
            }
            let mut nbits = 0u8;
            while (x >> nbits) >= 2 * q {
                nbits += 1;
            }
            bits += nbits as usize;
            let slot = (x >> nbits) - q;
            x = TABLE_SIZE as u32
                + self.state_of[(self.cumul[sym as usize] + slot) as usize] as u32;
        }
        Ok(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::FreqTable;

    #[test]
    fn empty_input_encodes_to_zero_bytes() {
        let mut freq = FreqTable::new();
        freq.add_symbols(&[1, 2, 3]);
        let enc = Encoder::new(&AnsTable::build(&freq).unwrap());
        assert!(enc.encode_to_vec(&[]).unwrap().is_empty());
        assert_eq!(enc.bit_len(&[]).unwrap(), 0);
    }

    #[test]
    fn unknown_symbol_is_rejected() {
        let mut freq = FreqTable::new();
        freq.add_symbols(&[1, 2, 3]);
        let enc = Encoder::new(&AnsTable::build(&freq).unwrap());
        assert!(enc.encode_to_vec(&[9]).is_err());
    }

    #[test]
    fn degenerate_run_pads_to_one_bit_per_symbol_floor() {
        let mut freq = FreqTable::new();
        freq.add_symbols(&[7; 100]);
        let enc = Encoder::new(&AnsTable::build(&freq).unwrap());
        let bytes = enc.encode_to_vec(&[7; 100]).unwrap();
        // Raw stream is just the 12-bit header (every step emits 0
        // bits); the pad lifts it to ceil(100/8) = 13 bytes.
        assert_eq!(enc.bit_len(&[7; 100]).unwrap(), TABLE_LOG as usize);
        assert_eq!(bytes.len(), 13);
    }

    #[test]
    fn encoded_len_matches_bit_len_modulo_padding() {
        let mut rng = crate::rng::Rng::new(0xA5);
        let syms: Vec<u8> = (0..4000).map(|_| (rng.below(16) * rng.below(2)) as u8).collect();
        let mut freq = FreqTable::new();
        freq.add_symbols(&syms);
        let enc = Encoder::new(&AnsTable::build(&freq).unwrap());
        let bytes = enc.encode_to_vec(&syms).unwrap();
        let bits = enc.bit_len(&syms).unwrap();
        assert_eq!(bytes.len(), bits.div_ceil(8).max(min_stream_bytes(syms.len())));
    }
}
