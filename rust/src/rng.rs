//! Deterministic, dependency-free pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so this module provides the
//! small set of distributions the rest of the crate needs: uniform
//! integers/floats, Gaussians (Box–Muller), categorical sampling, and
//! Fisher–Yates shuffling. The generator is xoshiro256++ seeded through
//! splitmix64 — the same construction `rand`'s `SmallRng` historically
//! used, chosen here for reproducibility of every experiment in
//! EXPERIMENTS.md (all benches pass fixed seeds).

/// xoshiro256++ PRNG with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free multiply-shift is fine here; bias is
        // negligible for the n (< 2^32) we use, but reject to be exact.
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in `[lo, hi)` as f32.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean and standard deviation, as f32.
    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        (mean as f64 + std as f64 * self.gaussian()) as f32
    }

    /// Fill a vector with `n` iid `N(mean, std^2)` samples.
    pub fn gaussian_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.gaussian_f32(mean, std)).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Panics if the weights sum to zero.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }
}
