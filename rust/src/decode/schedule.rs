//! Segment→thread assignment strategies.
//!
//! The paper's strategy is **shuffled round-robin** (§III-C): shuffle the
//! segment list, then deal segments to threads like cards, so each thread
//! gets a statistically balanced mixture of cheap and expensive segments.
//! [`Strategy::Contiguous`] (no shuffle) and [`Strategy::LargestFirst`]
//! (greedy bin-packing by encoded size — a natural "smarter" comparator)
//! exist for the `ablation_decode` bench.

use crate::rng::Rng;
use crate::store::ElmModel;

/// A computed assignment: layer indices per thread.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// `per_thread[t]` lists the layer indices thread `t` decodes.
    pub per_thread: Vec<Vec<usize>>,
}

impl Assignment {
    /// Encoded bytes each thread is responsible for.
    pub fn bytes_per_thread(&self, model: &ElmModel) -> Vec<usize> {
        self.per_thread
            .iter()
            .map(|idxs| idxs.iter().map(|&i| model.layers[i].encoded_len).sum())
            .collect()
    }

    /// Max/mean imbalance of encoded bytes across threads.
    pub fn byte_imbalance(&self, model: &ElmModel) -> f64 {
        let bytes = self.bytes_per_thread(model);
        let active: Vec<f64> = bytes.iter().map(|&b| b as f64).collect();
        let mean = active.iter().sum::<f64>() / active.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        active.iter().cloned().fold(0.0, f64::max) / mean
    }
}

/// Segment scheduling strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Paper §III-C: seeded shuffle, then round-robin deal.
    Shuffled {
        /// Shuffle seed (decode is deterministic for a fixed seed).
        seed: u64,
    },
    /// Round-robin in storage order (interleaved, no shuffle).
    Contiguous,
    /// Contiguous chunks: thread `t` gets segments `[t·n/T, (t+1)·n/T)` —
    /// the naive parameter-space split of the paper's Fig. 3, and the
    /// worst arm when expensive segments cluster (ablation_decode).
    Chunked,
    /// Greedy longest-processing-time bin packing by encoded bytes —
    /// needs sizes up front (the ELM manifest has them), included to
    /// show how close the paper's cheap shuffle gets to explicit packing.
    LargestFirst,
}

impl Strategy {
    /// Compute the per-thread layer lists for `model`.
    pub fn assign(&self, model: &ElmModel, threads: usize) -> Assignment {
        let sizes: Vec<usize> = model.layers.iter().map(|m| m.encoded_len).collect();
        self.assign_sizes(&sizes, threads)
    }

    /// Assignment from raw segment sizes (also used by the latency
    /// benches to evaluate scheduling over *hypothetical* segment
    /// structures, e.g. a phi3-shaped tensor list, without building the
    /// full container).
    pub fn assign_sizes(&self, sizes: &[usize], threads: usize) -> Assignment {
        let threads = threads.max(1);
        let n = sizes.len();
        let mut per_thread = vec![Vec::new(); threads];
        match *self {
            Strategy::Shuffled { seed } => {
                let mut order: Vec<usize> = (0..n).collect();
                Rng::new(seed).shuffle(&mut order);
                for (i, idx) in order.into_iter().enumerate() {
                    per_thread[i % threads].push(idx);
                }
            }
            Strategy::Contiguous => {
                for idx in 0..n {
                    per_thread[idx % threads].push(idx);
                }
            }
            Strategy::Chunked => {
                for idx in 0..n {
                    let t = (idx * threads) / n.max(1);
                    per_thread[t.min(threads - 1)].push(idx);
                }
            }
            Strategy::LargestFirst => {
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by_key(|&i| std::cmp::Reverse(sizes[i]));
                let mut load = vec![0usize; threads];
                for idx in order {
                    let t = (0..threads).min_by_key(|&t| load[t]).unwrap();
                    load[t] += sizes[idx];
                    per_thread[t].push(idx);
                }
            }
        }
        Assignment { per_thread }
    }

    /// Max/mean load imbalance of this strategy over raw segment sizes.
    pub fn imbalance_for_sizes(&self, sizes: &[usize], threads: usize) -> f64 {
        let a = self.assign_sizes(sizes, threads);
        let loads: Vec<f64> = a
            .per_thread
            .iter()
            .map(|idxs| idxs.iter().map(|&i| sizes[i] as f64).sum())
            .collect();
        let mean = loads.iter().sum::<f64>() / loads.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        loads.iter().cloned().fold(0.0, f64::max) / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitWidth;
    use crate::rng::Rng;
    use crate::store::compress;
    use crate::tensor::TensorF32;

    fn model(n_layers: usize, seed: u64) -> ElmModel {
        let mut rng = Rng::new(seed);
        let layers: Vec<(String, TensorF32)> = (0..n_layers)
            .map(|i| {
                let n = 100 + rng.below(5000);
                (
                    format!("l{i}"),
                    TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                )
            })
            .collect();
        compress(&layers, BitWidth::U8).unwrap().0
    }

    fn covers_exactly_once(a: &Assignment, n: usize) {
        let mut seen = vec![false; n];
        for list in &a.per_thread {
            for &i in list {
                assert!(!seen[i], "layer {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every layer assigned");
    }

    #[test]
    fn all_strategies_partition_the_parameter_space() {
        let m = model(37, 1);
        for strat in [
            Strategy::Shuffled { seed: 7 },
            Strategy::Contiguous,
            Strategy::Chunked,
            Strategy::LargestFirst,
        ] {
            for threads in [1, 2, 3, 4, 16, 64] {
                covers_exactly_once(&strat.assign(&m, threads), 37);
            }
        }
    }

    #[test]
    fn shuffle_is_deterministic_per_seed() {
        let m = model(20, 2);
        let a = Strategy::Shuffled { seed: 9 }.assign(&m, 4);
        let b = Strategy::Shuffled { seed: 9 }.assign(&m, 4);
        let c = Strategy::Shuffled { seed: 10 }.assign(&m, 4);
        assert_eq!(a.per_thread, b.per_thread);
        assert_ne!(a.per_thread, c.per_thread);
    }

    #[test]
    fn largest_first_beats_or_matches_contiguous_balance() {
        let m = model(50, 3);
        let lf = Strategy::LargestFirst.assign(&m, 4).byte_imbalance(&m);
        let cont = Strategy::Contiguous.assign(&m, 4).byte_imbalance(&m);
        assert!(lf <= cont + 1e-9, "LPT {lf} vs contiguous {cont}");
    }

    #[test]
    fn shuffled_balance_is_reasonable_on_many_segments() {
        // §III-C's claim: with many segments per thread, dealing a
        // shuffled list evens out the workload. Accept ≤ 1.5× mean.
        let m = model(200, 4);
        let imb = Strategy::Shuffled { seed: 0x5EED }
            .assign(&m, 4)
            .byte_imbalance(&m);
        assert!(imb < 1.5, "imbalance {imb}");
    }

    #[test]
    fn imbalance_for_sizes_matches_assignment() {
        let sizes: Vec<usize> = (1..=40).map(|i| i * 100).collect();
        let strat = Strategy::Shuffled { seed: 3 };
        let via_sizes = strat.imbalance_for_sizes(&sizes, 4);
        assert!(via_sizes >= 1.0);
        // LPT on many segments is near-perfect.
        let lpt = Strategy::LargestFirst.imbalance_for_sizes(&sizes, 4);
        assert!(lpt <= via_sizes + 1e-9);
        assert!(lpt < 1.05, "LPT imbalance {lpt}");
    }

    #[test]
    fn property_partition_for_random_models() {
        let mut rng = Rng::new(0xAB);
        for _ in 0..20 {
            let n = 1 + rng.below(60);
            let m = model(n, rng.next_u64());
            let threads = 1 + rng.below(9);
            let strat = match rng.below(3) {
                0 => Strategy::Shuffled { seed: rng.next_u64() },
                1 => Strategy::Contiguous,
                _ => Strategy::LargestFirst,
            };
            covers_exactly_once(&strat.assign(&m, threads), n);
        }
    }
}
