//! Segment→thread assignment strategies.
//!
//! The paper's strategy is **shuffled round-robin** (§III-C): shuffle the
//! segment list, then deal segments to threads like cards, so each thread
//! gets a statistically balanced mixture of cheap and expensive segments.
//! [`Strategy::Contiguous`] (no shuffle) and [`Strategy::LargestFirst`]
//! (greedy bin-packing by encoded size — a natural "smarter" comparator)
//! exist for the `ablation_decode` bench.

use crate::rng::Rng;
use crate::store::{ElmModel, LayerMeta};

/// Flatten a manifest's tile tables into `(layer, tile)` pairs in
/// execution order, alongside each tile's encoded byte size — the v2
/// unit of assignment. Scheduling tiles instead of layers is what lets
/// every worker attack a single hot layer instead of serializing behind
/// whoever owns it; for a v1 container (one synthesized tile per layer)
/// this degenerates to the classic per-layer assignment.
pub fn flat_tiles(layers: &[LayerMeta]) -> (Vec<(usize, usize)>, Vec<usize>) {
    let mut tiles = Vec::new();
    let mut sizes = Vec::new();
    for (li, l) in layers.iter().enumerate() {
        for (ti, t) in l.tiles.iter().enumerate() {
            tiles.push((li, ti));
            sizes.push(t.encoded_len);
        }
    }
    (tiles, sizes)
}

/// A computed assignment: layer indices per thread.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// `per_thread[t]` lists the layer indices thread `t` decodes.
    pub per_thread: Vec<Vec<usize>>,
}

impl Assignment {
    /// Encoded bytes each thread is responsible for.
    pub fn bytes_per_thread(&self, model: &ElmModel) -> Vec<usize> {
        self.per_thread
            .iter()
            .map(|idxs| idxs.iter().map(|&i| model.layers[i].encoded_len).sum())
            .collect()
    }

    /// Max/mean imbalance of encoded bytes across threads.
    pub fn byte_imbalance(&self, model: &ElmModel) -> f64 {
        let bytes = self.bytes_per_thread(model);
        let active: Vec<f64> = bytes.iter().map(|&b| b as f64).collect();
        let mean = active.iter().sum::<f64>() / active.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        active.iter().cloned().fold(0.0, f64::max) / mean
    }
}

/// Segment scheduling strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Paper §III-C: seeded shuffle, then round-robin deal.
    Shuffled {
        /// Shuffle seed (decode is deterministic for a fixed seed).
        seed: u64,
    },
    /// Round-robin in storage order (interleaved, no shuffle).
    Contiguous,
    /// Contiguous chunks: thread `t` gets segments `[t·n/T, (t+1)·n/T)` —
    /// the naive parameter-space split of the paper's Fig. 3, and the
    /// worst arm when expensive segments cluster (ablation_decode).
    Chunked,
    /// Greedy longest-processing-time bin packing by encoded bytes —
    /// needs sizes up front (the ELM manifest has them), included to
    /// show how close the paper's cheap shuffle gets to explicit packing.
    LargestFirst,
    /// Streaming assignment ([`crate::decode::StreamingDecoder`]): deal
    /// segments within consecutive execution-order windows of `window`
    /// segments, each window largest-first to the least-loaded thread
    /// (fewest segments, then fewest bytes). Globally this keeps every
    /// thread's list close to execution order — which a bounded
    /// prefetch window requires so the front of the window is always
    /// being decoded — while still balancing skewed segment sizes
    /// inside each window. Per-thread lists come out sorted ascending.
    Windowed {
        /// Window length in segments (the streaming decoder passes its
        /// prefetch depth).
        window: usize,
    },
}

impl Strategy {
    /// Compute the per-thread layer lists for `model`.
    pub fn assign(&self, model: &ElmModel, threads: usize) -> Assignment {
        let sizes: Vec<usize> = model.layers.iter().map(|m| m.encoded_len).collect();
        self.assign_sizes(&sizes, threads)
    }

    /// Assignment from raw segment sizes (also used by the latency
    /// benches to evaluate scheduling over *hypothetical* segment
    /// structures, e.g. a phi3-shaped tensor list, without building the
    /// full container).
    pub fn assign_sizes(&self, sizes: &[usize], threads: usize) -> Assignment {
        let threads = threads.max(1);
        let n = sizes.len();
        let mut per_thread = vec![Vec::new(); threads];
        match *self {
            Strategy::Shuffled { seed } => {
                let mut order: Vec<usize> = (0..n).collect();
                Rng::new(seed).shuffle(&mut order);
                for (i, idx) in order.into_iter().enumerate() {
                    per_thread[i % threads].push(idx);
                }
            }
            Strategy::Contiguous => {
                for idx in 0..n {
                    per_thread[idx % threads].push(idx);
                }
            }
            Strategy::Chunked => {
                for idx in 0..n {
                    let t = (idx * threads) / n.max(1);
                    per_thread[t.min(threads - 1)].push(idx);
                }
            }
            Strategy::LargestFirst => {
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by_key(|&i| std::cmp::Reverse(sizes[i]));
                let mut load = vec![0usize; threads];
                for idx in order {
                    let t = (0..threads).min_by_key(|&t| load[t]).unwrap();
                    load[t] += sizes[idx];
                    per_thread[t].push(idx);
                }
            }
            Strategy::Windowed { window } => {
                let w = window.max(1);
                let mut counts = vec![0usize; threads];
                let mut load = vec![0usize; threads];
                let mut start = 0usize;
                while start < n {
                    let end = (start + w).min(n);
                    let mut win: Vec<usize> = (start..end).collect();
                    win.sort_by_key(|&i| std::cmp::Reverse(sizes[i]));
                    for idx in win {
                        // Fewest segments first keeps counts within one
                        // of each other; byte load breaks ties.
                        let t = (0..threads)
                            .min_by_key(|&t| (counts[t], load[t], t))
                            .unwrap();
                        counts[t] += 1;
                        load[t] += sizes[idx];
                        per_thread[t].push(idx);
                    }
                    start = end;
                }
                // Each worker must decode its list in execution order.
                for list in per_thread.iter_mut() {
                    list.sort_unstable();
                }
            }
        }
        Assignment { per_thread }
    }

    /// Max/mean load imbalance of this strategy over raw segment sizes.
    pub fn imbalance_for_sizes(&self, sizes: &[usize], threads: usize) -> f64 {
        let a = self.assign_sizes(sizes, threads);
        let loads: Vec<f64> = a
            .per_thread
            .iter()
            .map(|idxs| idxs.iter().map(|&i| sizes[i] as f64).sum())
            .collect();
        let mean = loads.iter().sum::<f64>() / loads.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        loads.iter().cloned().fold(0.0, f64::max) / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitWidth;
    use crate::rng::Rng;
    use crate::store::compress;
    use crate::tensor::TensorF32;

    fn model(n_layers: usize, seed: u64) -> ElmModel {
        let mut rng = Rng::new(seed);
        let layers: Vec<(String, TensorF32)> = (0..n_layers)
            .map(|i| {
                let n = 100 + rng.below(5000);
                (
                    format!("l{i}"),
                    TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                )
            })
            .collect();
        compress(&layers, BitWidth::U8).unwrap().0
    }

    fn covers_exactly_once(a: &Assignment, n: usize) {
        let mut seen = vec![false; n];
        for list in &a.per_thread {
            for &i in list {
                assert!(!seen[i], "layer {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every layer assigned");
    }

    #[test]
    fn all_strategies_partition_the_parameter_space() {
        let m = model(37, 1);
        for strat in [
            Strategy::Shuffled { seed: 7 },
            Strategy::Contiguous,
            Strategy::Chunked,
            Strategy::LargestFirst,
        ] {
            for threads in [1, 2, 3, 4, 16, 64] {
                covers_exactly_once(&strat.assign(&m, threads), 37);
            }
        }
    }

    #[test]
    fn shuffle_is_deterministic_per_seed() {
        let m = model(20, 2);
        let a = Strategy::Shuffled { seed: 9 }.assign(&m, 4);
        let b = Strategy::Shuffled { seed: 9 }.assign(&m, 4);
        let c = Strategy::Shuffled { seed: 10 }.assign(&m, 4);
        assert_eq!(a.per_thread, b.per_thread);
        assert_ne!(a.per_thread, c.per_thread);
    }

    #[test]
    fn largest_first_beats_or_matches_contiguous_balance() {
        let m = model(50, 3);
        let lf = Strategy::LargestFirst.assign(&m, 4).byte_imbalance(&m);
        let cont = Strategy::Contiguous.assign(&m, 4).byte_imbalance(&m);
        assert!(lf <= cont + 1e-9, "LPT {lf} vs contiguous {cont}");
    }

    #[test]
    fn shuffled_balance_is_reasonable_on_many_segments() {
        // §III-C's claim: with many segments per thread, dealing a
        // shuffled list evens out the workload. Accept ≤ 1.5× mean.
        let m = model(200, 4);
        let imb = Strategy::Shuffled { seed: 0x5EED }
            .assign(&m, 4)
            .byte_imbalance(&m);
        assert!(imb < 1.5, "imbalance {imb}");
    }

    #[test]
    fn imbalance_for_sizes_matches_assignment() {
        let sizes: Vec<usize> = (1..=40).map(|i| i * 100).collect();
        let strat = Strategy::Shuffled { seed: 3 };
        let via_sizes = strat.imbalance_for_sizes(&sizes, 4);
        assert!(via_sizes >= 1.0);
        // LPT on many segments is near-perfect.
        let lpt = Strategy::LargestFirst.imbalance_for_sizes(&sizes, 4);
        assert!(lpt <= via_sizes + 1e-9);
        assert!(lpt < 1.05, "LPT imbalance {lpt}");
    }

    fn all_strategies() -> Vec<Strategy> {
        vec![
            Strategy::Shuffled { seed: 7 },
            Strategy::Contiguous,
            Strategy::Chunked,
            Strategy::LargestFirst,
            Strategy::Windowed { window: 4 },
            Strategy::Windowed { window: 1 },
        ]
    }

    #[test]
    fn every_segment_assigned_exactly_once_for_1_2_4_8_threads() {
        for n in [1usize, 2, 3, 7, 8, 37, 100] {
            let sizes: Vec<usize> = (0..n).map(|i| 50 + (i * 997) % 4000).collect();
            for strat in all_strategies() {
                for threads in [1usize, 2, 4, 8] {
                    let a = strat.assign_sizes(&sizes, threads);
                    assert_eq!(a.per_thread.len(), threads);
                    let mut seen = vec![false; n];
                    for list in &a.per_thread {
                        for &i in list {
                            assert!(!seen[i], "{strat:?} t{threads}: segment {i} twice");
                            seen[i] = true;
                        }
                    }
                    assert!(
                        seen.iter().all(|&s| s),
                        "{strat:?} t{threads}: some segment unassigned"
                    );
                }
            }
        }
    }

    #[test]
    fn no_thread_idle_while_another_holds_two_or_more() {
        // The fairness invariant behind every strategy: work only piles
        // two-deep on a thread once every thread has something to do.
        let mut rng = Rng::new(0x1D1E);
        for _ in 0..40 {
            let n = 1 + rng.below(50);
            // Heavily skewed sizes to stress the size-aware strategies.
            let sizes: Vec<usize> = (0..n)
                .map(|_| if rng.below(5) == 0 { 100_000 } else { 10 + rng.below(500) })
                .collect();
            for strat in all_strategies() {
                for threads in [1usize, 2, 4, 8] {
                    let a = strat.assign_sizes(&sizes, threads);
                    let counts: Vec<usize> = a.per_thread.iter().map(|l| l.len()).collect();
                    let min = *counts.iter().min().unwrap();
                    let max = *counts.iter().max().unwrap();
                    assert!(
                        !(min == 0 && max >= 2),
                        "{strat:?} t{threads} n{n}: idle thread while another holds {max}"
                    );
                }
            }
        }
    }

    #[test]
    fn windowed_streaming_lists_are_execution_ordered_and_count_balanced() {
        let mut rng = Rng::new(0x3AF);
        for _ in 0..25 {
            let n = 1 + rng.below(80);
            let sizes: Vec<usize> = (0..n).map(|_| 10 + rng.below(9000)).collect();
            let window = 1 + rng.below(8);
            for threads in [1usize, 2, 4, 8] {
                let a = Strategy::Windowed { window }.assign_sizes(&sizes, threads);
                let mut counts = Vec::new();
                for list in &a.per_thread {
                    // Ascending order is what the bounded prefetch window
                    // relies on for deadlock freedom.
                    assert!(
                        list.windows(2).all(|w| w[0] < w[1]),
                        "list not execution-ordered: {list:?}"
                    );
                    counts.push(list.len());
                }
                let min = *counts.iter().min().unwrap();
                let max = *counts.iter().max().unwrap();
                assert!(max - min <= 1, "counts {counts:?} spread > 1");
            }
        }
    }

    #[test]
    fn flat_tiles_cover_every_tile_in_execution_order() {
        let m = model(12, 5);
        let (tiles, sizes) = flat_tiles(&m.layers);
        let total: usize = m.layers.iter().map(|l| l.tiles.len()).sum();
        assert_eq!(tiles.len(), total);
        assert!(total > m.layers.len(), "fixture must have multi-tile layers");
        assert_eq!(sizes.iter().sum::<usize>(), m.payload.len());
        assert!(tiles.windows(2).all(|w| w[0] < w[1]), "execution order");
        for (k, &(li, ti)) in tiles.iter().enumerate() {
            assert_eq!(sizes[k], m.layers[li].tiles[ti].encoded_len);
        }
    }

    #[test]
    fn property_partition_for_random_models() {
        let mut rng = Rng::new(0xAB);
        for _ in 0..20 {
            let n = 1 + rng.below(60);
            let m = model(n, rng.next_u64());
            let threads = 1 + rng.below(9);
            let strat = match rng.below(3) {
                0 => Strategy::Shuffled { seed: rng.next_u64() },
                1 => Strategy::Contiguous,
                _ => Strategy::LargestFirst,
            };
            covers_exactly_once(&strat.assign(&m, threads), n);
        }
    }
}
