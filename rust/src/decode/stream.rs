//! Streaming layer-ahead ELM decode with a **bounded prefetch window**.
//!
//! [`super::ParallelDecoder`] realizes the paper's §III-C decode — but
//! as a barrier: the engine sees no weights until *every* segment has
//! been decoded, so time-to-first-token pays the whole decode up front
//! (the serial bottleneck Huff-LLM, arXiv:2502.00922, pipelines away).
//! [`StreamingDecoder`] removes the barrier: worker threads walk the
//! container's segments in execution order and the consumer receives
//! each [`QuantizedTensor`] the moment it is ready, in order, while
//! later layers are still being decoded.
//!
//! The window is bounded: workers never run more than
//! `prefetch_layers` layers ahead of the consumer's cursor, so peak
//! resident decoded-but-unconsumed memory is `O(window)` layers instead
//! of the whole model — the property that lets a memory-limited edge
//! device start serving before the model fits decoded in RAM.
//!
//! Concurrency shape: one [`Strategy::Windowed`] static assignment of
//! **tiles** (each worker's list ascending in execution order), one
//! mutex-guarded exchange holding at most `window` decoded layers, two
//! condvars (consumer waits for the next layer; workers wait for window
//! space). Workers decode tiles and assemble them into per-layer
//! buffers; the last tile seals the layer — so every worker can attack
//! the front of the window even when it is a single hot layer.
//! Deadlock freedom: the consumer always waits for layer `delivered`,
//! and any worker owning one of `delivered`'s tiles is never
//! window-blocked because its ascending cursor is at a tile of some
//! layer `<= delivered < delivered + window`.
//!
//! The stream runs over any [`SegmentSource`]: with a file-backed
//! source ([`SegmentSource::open`]) segments are read from disk only as
//! the window admits them, so a streaming load never holds the whole
//! encoded payload either. [`SegmentDecoder`] is the **re-entrant**
//! sibling — random-access, repeatable per-layer decode — which is what
//! the weight-residency cache ([`crate::residency`]) faults evicted
//! layers back in with.

use super::schedule::Strategy;
use super::ThreadStats;
use crate::codec::CodecSet;
use crate::quant::QuantizedTensor;
use crate::store::{ElmModel, SegmentSource};
use crate::tensor::TensorU8;
use crate::{Error, Result};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Streaming decode configuration.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Worker thread count (`T` in Algorithm 1).
    pub threads: usize,
    /// Prefetch window: decoded-but-undelivered layers never exceed
    /// this bound (>= 1).
    pub prefetch_layers: usize,
    /// Segment→worker assignment. Defaults to
    /// [`Strategy::Windowed`] with `window = prefetch_layers`.
    pub strategy: Strategy,
}

impl StreamConfig {
    /// Config with the default (windowed) assignment.
    pub fn new(threads: usize, prefetch_layers: usize) -> Self {
        let prefetch = prefetch_layers.max(1);
        StreamConfig {
            threads: threads.max(1),
            prefetch_layers: prefetch,
            strategy: Strategy::Windowed { window: prefetch },
        }
    }
}

/// One decoded layer, delivered in execution order.
#[derive(Debug, Clone)]
pub struct DecodedLayer {
    /// Layer index in execution (storage) order.
    pub index: usize,
    /// Layer name from the container manifest.
    pub name: String,
    /// The decoded quantized tensor.
    pub tensor: QuantizedTensor,
}

/// Accounting for one streaming decode.
#[derive(Debug, Clone)]
pub struct StreamStats {
    /// Wallclock from stream start to the stats snapshot.
    pub wall: Duration,
    /// Stream start → first layer delivered (the streaming win: for a
    /// prefetch window `w` of `L` layers this is ~`w/L` of the full
    /// decode instead of all of it).
    pub time_to_first_layer: Duration,
    /// Configured prefetch bound.
    pub prefetch_layers: usize,
    /// Largest number of decoded-but-undelivered layers resident at
    /// once — the true memory high-water mark of the window; always
    /// `<= prefetch_layers`.
    pub max_layers_ahead: usize,
    /// Per-worker accounting (busy excludes window waits).
    pub threads: Vec<ThreadStats>,
}

impl StreamStats {
    /// Total symbols decoded.
    pub fn total_symbols(&self) -> usize {
        self.threads.iter().map(|t| t.symbols).sum()
    }

    /// Total encoded bytes consumed.
    pub fn total_encoded_bytes(&self) -> usize {
        self.threads.iter().map(|t| t.encoded_bytes).sum()
    }

    /// Aggregate decode throughput, symbols/second.
    pub fn symbols_per_sec(&self) -> f64 {
        self.total_symbols() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// View as the eager-path stats type (shared reporting helpers).
    pub fn decode_stats(&self) -> super::DecodeStats {
        super::DecodeStats {
            wall: self.wall,
            threads: self.threads.clone(),
        }
    }
}

struct State {
    /// Consumer cursor: layers `< delivered` have been handed out.
    delivered: usize,
    /// Decoded-but-undelivered layers (at most `window` are `Some`).
    ready: Vec<Option<QuantizedTensor>>,
    /// In-progress layer assembly: symbol buffer + tiles still missing.
    /// Workers decode *tiles*; the last tile to land seals the layer
    /// into `ready`. Only layers inside the window can have an entry.
    partial: Vec<Option<(Vec<u8>, usize)>>,
    /// First decode failure; poisons the stream.
    error: Option<Error>,
    /// Set when the consumer goes away; workers drain out.
    cancelled: bool,
    /// Decoded-but-undelivered layers currently resident (`Some`
    /// entries in `ready`).
    resident: usize,
    /// High-water mark of `resident`.
    max_resident: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for window space.
    space: Condvar,
    /// The consumer waits here for the next layer.
    avail: Condvar,
    window: usize,
}

/// Streaming decoder over an [`ElmModel`].
#[derive(Debug, Clone)]
pub struct StreamingDecoder {
    /// Configuration.
    pub cfg: StreamConfig,
}

impl StreamingDecoder {
    /// Decoder with `threads` workers and a `prefetch_layers` window.
    pub fn new(threads: usize, prefetch_layers: usize) -> Self {
        StreamingDecoder {
            cfg: StreamConfig::new(threads, prefetch_layers),
        }
    }

    /// Override the assignment strategy. The strategy decides only
    /// *which worker owns which segments*; each worker always decodes
    /// its list in ascending execution order (the stream re-sorts every
    /// list), because a worker holding an out-of-order list could
    /// window-block on a late layer while the consumer waits on its
    /// early one — the sort is what makes any strategy deadlock-free
    /// here.
    pub fn with_strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// Start decoding an in-memory container: spawns the worker pool and
    /// returns the consumer handle. Layers are delivered strictly in
    /// execution order. (Convenience wrapper over
    /// [`StreamingDecoder::stream_source`] with a memory backing.)
    pub fn stream(&self, model: Arc<ElmModel>) -> Result<LayerStream> {
        self.stream_source(Arc::new(SegmentSource::from_model(model)))
    }

    /// Start decoding over any [`SegmentSource`]. With a file-backed
    /// source ([`SegmentSource::open`]) each worker reads its segment
    /// from disk only when the window admits it, so peak RSS during a
    /// streaming load is `O(prefetch window)` decoded layers plus
    /// `O(window)` encoded segments — never the whole payload.
    pub fn stream_source(&self, source: Arc<SegmentSource>) -> Result<LayerStream> {
        let codecs = Arc::new(CodecSet::new(source.code(), source.ans_table())?);
        let n = source.n_layers();
        // The unit of claim is the **tile** (v2): a hot layer's tiles
        // are dealt across the pool, so every worker can help the front
        // of the window instead of queueing behind one owner. Flat tile
        // order is execution order, so window gating stays per layer.
        let (tiles, sizes) = crate::decode::flat_tiles(source.layers());
        let assignment = self.cfg.strategy.assign_sizes(&sizes, self.cfg.threads);
        let tiles = Arc::new(tiles);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                delivered: 0,
                ready: (0..n).map(|_| None).collect(),
                partial: (0..n).map(|_| None).collect(),
                error: None,
                cancelled: false,
                resident: 0,
                max_resident: 0,
            }),
            space: Condvar::new(),
            avail: Condvar::new(),
            window: self.cfg.prefetch_layers,
        });
        let started = Instant::now();
        let mut handles = Vec::with_capacity(assignment.per_thread.len());
        for indices in &assignment.per_thread {
            let mut indices = indices.clone();
            // Ascending execution order within each worker is what makes
            // the bounded window deadlock-free (see `with_strategy`);
            // a no-op for the default Windowed assignment, which is
            // already sorted.
            indices.sort_unstable();
            let source = Arc::clone(&source);
            let codecs = Arc::clone(&codecs);
            let shared = Arc::clone(&shared);
            let tiles = Arc::clone(&tiles);
            handles.push(std::thread::spawn(move || {
                worker(&source, &codecs, &shared, &tiles, indices)
            }));
        }
        Ok(LayerStream {
            source,
            shared,
            handles,
            next: 0,
            n,
            started,
            first_layer: None,
            poisoned: false,
        })
    }

    /// Decode a whole model through the streaming path, collecting the
    /// tensors in layer order (equivalence harness for tests/benches;
    /// real consumers drain the [`LayerStream`] incrementally). Takes
    /// the container by `Arc` so the (potentially GB-scale) payload is
    /// shared with the workers, never copied.
    pub fn decode_model(
        &self,
        model: Arc<ElmModel>,
    ) -> Result<(Vec<QuantizedTensor>, StreamStats)> {
        let mut stream = self.stream(model)?;
        let mut out = Vec::with_capacity(stream.total_layers());
        while let Some(layer) = stream.next_layer() {
            out.push(layer?.tensor);
        }
        Ok((out, stream.into_stats()))
    }
}

/// **Re-entrant per-layer decode** over a [`SegmentSource`]: decode any
/// layer, any number of times, in any order.
///
/// [`LayerStream`] is the in-order pipeline for *loading*; this is its
/// random-access counterpart for *serving* — the fault-in path of the
/// weight-residency cache ([`crate::residency::WeightCache`]), which
/// must re-decode an evicted layer mid-generation. Per-segment CRC-32
/// verification runs on every call, so random re-entry is as guarded as
/// the sequential walk.
pub struct SegmentDecoder {
    source: Arc<SegmentSource>,
    codecs: CodecSet,
}

impl SegmentDecoder {
    /// Build the decode tables once for the source's model-global
    /// code(s) — Huffman always, tANS when the container carries its
    /// table.
    pub fn new(source: Arc<SegmentSource>) -> Result<Self> {
        let codecs = CodecSet::new(source.code(), source.ans_table())?;
        Ok(SegmentDecoder { source, codecs })
    }

    /// The source this decoder reads from.
    pub fn source(&self) -> &Arc<SegmentSource> {
        &self.source
    }

    /// Decode layer `index` behind CRC verification. Bit-identical to
    /// what the eager and streaming paths produce for the same layer.
    pub fn decode_layer(&self, index: usize) -> Result<QuantizedTensor> {
        if index >= self.source.n_layers() {
            return Err(Error::InvalidArg(format!(
                "layer index {index} out of range ({} layers)",
                self.source.n_layers()
            )));
        }
        decode_one(&self.source, &self.codecs, index)
    }

    /// [`SegmentDecoder::decode_layer`] plus the per-worker accounting
    /// the streaming workers keep (`segments`, `encoded_bytes`,
    /// `symbols`, `busy`) folded into `stats` — shared by the
    /// residency cache's synchronous fault path and the decode-ahead
    /// prefetch pool ([`crate::residency::prefetch`]). `segments`
    /// counts **tiles**, the v2 unit of decode work.
    pub fn decode_layer_stats(
        &self,
        index: usize,
        stats: &mut ThreadStats,
    ) -> Result<QuantizedTensor> {
        let t0 = Instant::now();
        let tensor = self.decode_layer(index)?;
        let meta = self.source.meta(index);
        stats.segments += meta.tiles.len();
        stats.encoded_bytes += meta.encoded_len;
        stats.symbols += meta.n_symbols;
        stats.busy += t0.elapsed();
        Ok(tensor)
    }

    /// Decode a single tile of layer `index` behind the tile's own CRC,
    /// returning its decoded symbols — the claim unit of the
    /// decode-ahead prefetcher, which assembles tiles into a layer
    /// buffer itself.
    pub fn decode_tile(&self, index: usize, t: usize) -> Result<Vec<u8>> {
        if index >= self.source.n_layers() {
            return Err(Error::InvalidArg(format!(
                "layer index {index} out of range ({} layers)",
                self.source.n_layers()
            )));
        }
        decode_one_tile(&self.source, &self.codecs, index, t)
    }
}

/// The one per-layer decode body: per-tile CRC-verified reads → table
/// decode (with the layer's own codec) into the layer's symbol buffer
/// → tensor. Shared by the serving fault path and the re-entrant
/// [`SegmentDecoder`] so decode output is bit-identical to the eager
/// and streaming paths, for v1/v2/v3 containers alike.
fn decode_one(source: &SegmentSource, codecs: &CodecSet, index: usize) -> Result<QuantizedTensor> {
    let meta = source.meta(index);
    let dec = codecs.get(meta.codec)?;
    let mut buf = vec![0u8; meta.n_symbols];
    for (t, tile) in meta.tiles.iter().enumerate() {
        let seg = source.verified_tile(index, t)?;
        let out = &mut buf[tile.sym_offset..tile.sym_offset + tile.n_symbols];
        dec.decode_tile(&seg, out)?;
    }
    Ok(QuantizedTensor {
        symbols: TensorU8::new(meta.shape.clone(), buf)?,
        params: meta.params,
    })
}

/// Decode one tile of a layer into its own symbol buffer, behind the
/// tile's CRC, with the layer's codec.
fn decode_one_tile(
    source: &SegmentSource,
    codecs: &CodecSet,
    index: usize,
    t: usize,
) -> Result<Vec<u8>> {
    let meta = source.meta(index);
    let tile = &meta.tiles[t];
    let seg = source.verified_tile(index, t)?;
    let mut buf = vec![0u8; tile.n_symbols];
    codecs.get(meta.codec)?.decode_tile(&seg, &mut buf)?;
    Ok(buf)
}

fn worker(
    source: &SegmentSource,
    codecs: &CodecSet,
    shared: &Shared,
    tiles: &[(usize, usize)],
    indices: Vec<usize>,
) -> ThreadStats {
    let mut stats = ThreadStats {
        segments: 0,
        encoded_bytes: 0,
        symbols: 0,
        busy: Duration::ZERO,
    };
    for flat in indices {
        let (layer, t) = tiles[flat];
        // Bounded prefetch: block until this tile's *layer* is inside
        // the window. With a file-backed source this also bounds *disk
        // reads*: a tile's bytes are only pulled once the window admits
        // its layer.
        {
            let mut st = shared.state.lock().unwrap();
            while layer >= st.delivered + shared.window
                && st.error.is_none()
                && !st.cancelled
            {
                st = shared.space.wait(st).unwrap();
            }
            if st.error.is_some() || st.cancelled {
                return stats;
            }
        }

        let t0 = Instant::now();
        let meta = source.meta(layer);
        let tile = &meta.tiles[t];
        let result = decode_one_tile(source, codecs, layer, t);
        stats.busy += t0.elapsed();

        let mut st = shared.state.lock().unwrap();
        match result {
            Ok(tile_syms) => {
                stats.segments += 1;
                stats.encoded_bytes += tile.encoded_len;
                stats.symbols += tile.n_symbols;
                let sealed = {
                    let entry = st.partial[layer]
                        .get_or_insert_with(|| (vec![0u8; meta.n_symbols], meta.tiles.len()));
                    entry.0[tile.sym_offset..tile.sym_offset + tile.n_symbols]
                        .copy_from_slice(&tile_syms);
                    entry.1 -= 1;
                    entry.1 == 0
                };
                if sealed {
                    // Last tile seals the layer.
                    let (buf, _) = st.partial[layer].take().unwrap();
                    match TensorU8::new(meta.shape.clone(), buf) {
                        Ok(symbols) => {
                            // All resident layers lie in `[delivered,
                            // delivered + window)`, so the high-water
                            // mark is bounded by the prefetch window.
                            st.resident += 1;
                            st.max_resident = st.max_resident.max(st.resident);
                            st.ready[layer] = Some(QuantizedTensor {
                                symbols,
                                params: meta.params,
                            });
                            shared.avail.notify_all();
                        }
                        Err(e) => {
                            if st.error.is_none() {
                                st.error = Some(e);
                            }
                            shared.avail.notify_all();
                            shared.space.notify_all();
                            return stats;
                        }
                    }
                }
            }
            Err(e) => {
                if st.error.is_none() {
                    st.error = Some(e);
                }
                shared.avail.notify_all();
                shared.space.notify_all();
                return stats;
            }
        }
    }
    stats
}

/// Consumer handle of a streaming decode: yields layers in execution
/// order as they become available, then exposes the run's stats.
pub struct LayerStream {
    source: Arc<SegmentSource>,
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<ThreadStats>>,
    next: usize,
    n: usize,
    started: Instant,
    first_layer: Option<Duration>,
    poisoned: bool,
}

impl LayerStream {
    /// Total layers this stream will deliver.
    pub fn total_layers(&self) -> usize {
        self.n
    }

    /// Layers delivered so far.
    pub fn delivered(&self) -> usize {
        self.next
    }

    /// Blocking pull of the next layer (in execution order). Returns
    /// `None` when every layer has been delivered, or after an error
    /// has been yielded once.
    pub fn next_layer(&mut self) -> Option<Result<DecodedLayer>> {
        if self.poisoned || self.next >= self.n {
            return None;
        }
        let idx = self.next;
        let mut st = self.shared.state.lock().unwrap();
        let tensor = loop {
            if let Some(e) = st.error.take() {
                st.cancelled = true;
                self.shared.space.notify_all();
                self.shared.avail.notify_all();
                drop(st);
                self.poisoned = true;
                return Some(Err(e));
            }
            if let Some(tensor) = st.ready[idx].take() {
                st.delivered = idx + 1;
                st.resident -= 1;
                break tensor;
            }
            st = self.shared.avail.wait(st).unwrap();
        };
        drop(st);
        // Window space opened up.
        self.shared.space.notify_all();
        if self.first_layer.is_none() {
            self.first_layer = Some(self.started.elapsed());
        }
        self.next += 1;
        Some(Ok(DecodedLayer {
            index: idx,
            name: self.source.meta(idx).name.clone(),
            tensor,
        }))
    }

    /// Finish the stream: cancel any remaining work, join the workers,
    /// and return the accounting.
    pub fn into_stats(mut self) -> StreamStats {
        self.take_stats()
    }

    fn cancel(&self) {
        let mut st = self.shared.state.lock().unwrap();
        st.cancelled = true;
        drop(st);
        self.shared.space.notify_all();
        self.shared.avail.notify_all();
    }

    fn take_stats(&mut self) -> StreamStats {
        self.cancel();
        let threads: Vec<ThreadStats> = self
            .handles
            .drain(..)
            .map(|h| h.join().expect("stream worker panicked"))
            .collect();
        let st = self.shared.state.lock().unwrap();
        StreamStats {
            wall: self.started.elapsed(),
            time_to_first_layer: self.first_layer.unwrap_or_default(),
            prefetch_layers: self.shared.window,
            max_layers_ahead: st.max_resident,
            threads,
        }
    }
}

impl Iterator for LayerStream {
    type Item = Result<DecodedLayer>;

    fn next(&mut self) -> Option<Result<DecodedLayer>> {
        self.next_layer()
    }
}

impl Drop for LayerStream {
    fn drop(&mut self) {
        self.cancel();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::ParallelDecoder;
    use crate::quant::{quantize_mixed, BitWidth};
    use crate::rng::Rng;
    use crate::store::compress;
    use crate::tensor::TensorF32;

    fn model_with_layers(
        n_layers: usize,
        seed: u64,
        bits: BitWidth,
    ) -> (Vec<(String, TensorF32)>, ElmModel) {
        let mut rng = Rng::new(seed);
        let layers: Vec<(String, TensorF32)> = (0..n_layers)
            .map(|i| {
                let n = 64 + rng.below(3000) * (1 + i % 3);
                (
                    format!("blocks.{i}.w"),
                    TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                )
            })
            .collect();
        let (model, _) = compress(&layers, bits).unwrap();
        (layers, model)
    }

    #[test]
    fn streaming_equals_eager_decode_bitexact() {
        let (layers, model) = model_with_layers(19, 0x51, BitWidth::U8);
        let (eager, _) = ParallelDecoder::new(4).decode_model(&model).unwrap();
        let model = Arc::new(model);
        for threads in [1usize, 2, 4] {
            for prefetch in [1usize, 2, 5, 100] {
                let (streamed, stats) = StreamingDecoder::new(threads, prefetch)
                    .decode_model(Arc::clone(&model))
                    .unwrap();
                assert_eq!(streamed.len(), layers.len());
                for (a, b) in eager.iter().zip(&streamed) {
                    assert_eq!(a.symbols.data(), b.symbols.data());
                    assert_eq!(a.params, b.params);
                }
                assert_eq!(stats.total_symbols(), model.n_params());
                assert_eq!(stats.total_encoded_bytes(), model.payload.len());
            }
        }
    }

    #[test]
    fn layers_arrive_in_execution_order_with_names() {
        let (_, model) = model_with_layers(11, 0x52, BitWidth::U4);
        let model = Arc::new(model);
        let mut stream = StreamingDecoder::new(3, 2)
            .stream(Arc::clone(&model))
            .unwrap();
        let mut expected = 0usize;
        while let Some(layer) = stream.next_layer() {
            let layer = layer.unwrap();
            assert_eq!(layer.index, expected);
            assert_eq!(layer.name, model.layers[expected].name);
            let direct = crate::store::decode_layer(&model, expected).unwrap();
            assert_eq!(layer.tensor.symbols.data(), direct.symbols.data());
            expected += 1;
        }
        assert_eq!(expected, model.layers.len());
    }

    #[test]
    fn prefetch_window_bound_is_respected() {
        let (_, model) = model_with_layers(24, 0x53, BitWidth::U8);
        let model = Arc::new(model);
        for prefetch in [1usize, 2, 4] {
            let (_, stats) = StreamingDecoder::new(4, prefetch)
                .decode_model(Arc::clone(&model))
                .unwrap();
            assert!(stats.max_layers_ahead >= 1);
            assert!(
                stats.max_layers_ahead <= prefetch,
                "window {prefetch} exceeded: ahead {}",
                stats.max_layers_ahead
            );
        }
    }

    #[test]
    fn corrupt_segment_poisons_the_stream() {
        let (_, mut model) = model_with_layers(9, 0x54, BitWidth::U8);
        let off = model.layers[4].offset;
        model.payload[off] ^= 0xFF;
        let mut stream = StreamingDecoder::new(2, 2)
            .stream(Arc::new(model))
            .unwrap();
        let mut saw_error = false;
        let mut delivered = 0usize;
        while let Some(layer) = stream.next_layer() {
            match layer {
                Ok(_) => delivered += 1,
                Err(e) => {
                    saw_error = true;
                    assert!(e.to_string().contains("CRC"), "{e}");
                }
            }
        }
        assert!(saw_error, "corruption must surface");
        assert!(delivered < 9, "stream must stop early");
        // Workers must all unwind (into_stats would hang otherwise).
        let _ = stream.into_stats();
    }

    #[test]
    fn dropping_a_stream_midway_does_not_hang() {
        let (_, model) = model_with_layers(16, 0x55, BitWidth::U8);
        let mut stream = StreamingDecoder::new(4, 2)
            .stream(Arc::new(model))
            .unwrap();
        // Take two layers, then walk away; Drop must cancel + join.
        assert!(stream.next_layer().unwrap().is_ok());
        assert!(stream.next_layer().unwrap().is_ok());
        drop(stream);
    }

    #[test]
    fn single_layer_single_thread_minimal_window() {
        let (_, model) = model_with_layers(1, 0x56, BitWidth::U4);
        let (tensors, stats) = StreamingDecoder::new(1, 1)
            .decode_model(Arc::new(model))
            .unwrap();
        assert_eq!(tensors.len(), 1);
        assert_eq!(stats.max_layers_ahead, 1);
        assert!(stats.time_to_first_layer <= stats.wall);
    }

    #[test]
    fn stats_account_for_all_work_across_workers() {
        let (_, model) = model_with_layers(23, 0x57, BitWidth::U4);
        let model = Arc::new(model);
        let (_, stats) = StreamingDecoder::new(4, 3)
            .decode_model(Arc::clone(&model))
            .unwrap();
        // Workers claim tiles (v2), so `segments` counts tiles.
        let segs: usize = stats.threads.iter().map(|t| t.segments).sum();
        let tiles: usize = model.layers.iter().map(|l| l.tiles.len()).sum();
        assert_eq!(segs, tiles);
        assert_eq!(stats.total_symbols(), model.n_params());
        assert_eq!(stats.total_encoded_bytes(), model.payload.len());
        assert_eq!(stats.prefetch_layers, 3);
    }

    #[test]
    fn file_backed_stream_source_equals_in_memory_stream() {
        use crate::store::SegmentSource;
        let (_, model) = model_with_layers(14, 0x58, BitWidth::U8);
        let dir = std::env::temp_dir().join(format!("elm_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();

        let (eager, _) = ParallelDecoder::new(4).decode_model(&model).unwrap();
        let lazy = Arc::new(SegmentSource::open(&path).unwrap());
        let mut stream = StreamingDecoder::new(3, 2).stream_source(lazy).unwrap();
        let mut streamed = Vec::new();
        while let Some(layer) = stream.next_layer() {
            streamed.push(layer.unwrap().tensor);
        }
        let stats = stream.into_stats();
        assert_eq!(streamed.len(), eager.len());
        for (a, b) in eager.iter().zip(&streamed) {
            assert_eq!(a.symbols.data(), b.symbols.data());
            assert_eq!(a.params, b.params);
        }
        assert_eq!(stats.total_symbols(), model.n_params());
        assert!(stats.max_layers_ahead <= 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tans_containers_stream_and_reenter_bitexact() {
        // The whole streaming stack — windowed workers, file-backed
        // source, re-entrant SegmentDecoder — over a tANS container
        // must reproduce exactly what the Huffman container yields.
        use crate::store::{compress_with_options, CodecChoice, SegmentSource};
        let mut rng = Rng::new(0x5A);
        let layers: Vec<(String, TensorF32)> = (0..12)
            .map(|i| {
                let n = 64 + rng.below(3000);
                (
                    format!("blocks.{i}.w"),
                    TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                )
            })
            .collect();
        let (hm, _) =
            compress_with_options(&layers, BitWidth::U8, Some(512), CodecChoice::Huffman).unwrap();
        let (am, _) =
            compress_with_options(&layers, BitWidth::U8, Some(512), CodecChoice::Ans).unwrap();
        let (want, _) = ParallelDecoder::new(2).decode_model(&hm).unwrap();

        let dir = std::env::temp_dir().join(format!("elm_anstream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ans.elm");
        am.save(&path).unwrap();

        // In-memory streaming.
        let (streamed, stats) = StreamingDecoder::new(3, 2)
            .decode_model(Arc::new(am))
            .unwrap();
        assert_eq!(stats.total_symbols(), hm.n_params());
        for (a, b) in want.iter().zip(&streamed) {
            assert_eq!(a.symbols.data(), b.symbols.data());
        }

        // File-backed streaming + random re-entry.
        let lazy = Arc::new(SegmentSource::open(&path).unwrap());
        assert!(lazy.ans_table().is_some());
        let mut stream = StreamingDecoder::new(2, 2)
            .stream_source(Arc::clone(&lazy))
            .unwrap();
        let mut i = 0usize;
        while let Some(layer) = stream.next_layer() {
            assert_eq!(layer.unwrap().tensor.symbols.data(), want[i].symbols.data());
            i += 1;
        }
        assert_eq!(i, layers.len());
        let reent = SegmentDecoder::new(lazy).unwrap();
        for &i in &[11usize, 0, 5, 11, 3] {
            assert_eq!(
                reent.decode_layer(i).unwrap().symbols.data(),
                want[i].symbols.data()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_decoder_random_reentry_is_bitexact() {
        use crate::store::SegmentSource;
        let (_, model) = model_with_layers(10, 0x59, BitWidth::U4);
        let dir = std::env::temp_dir().join(format!("elm_reent_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.elm");
        model.save(&path).unwrap();

        let mem = SegmentDecoder::new(Arc::new(SegmentSource::from_model(Arc::new(
            model.clone(),
        ))))
        .unwrap();
        let lazy = SegmentDecoder::new(Arc::new(SegmentSource::open(&path).unwrap())).unwrap();

        // Arbitrary revisit-heavy order: every decode must match the
        // serial reference, on both backings.
        for &i in &[7usize, 0, 9, 7, 3, 0, 9, 9, 1] {
            let want = crate::store::decode_layer(&model, i).unwrap();
            for dec in [&mem, &lazy] {
                let got = dec.decode_layer(i).unwrap();
                assert_eq!(got.symbols.data(), want.symbols.data());
                assert_eq!(got.params, want.params);
            }
        }
        assert!(mem.decode_layer(10).is_err(), "out of range must error");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn property_streaming_lossless_for_random_shapes() {
        let mut rng = Rng::new(0xF1F);
        for _ in 0..8 {
            let n_layers = 1 + rng.below(14);
            let (layers, model) = model_with_layers(n_layers, rng.next_u64(), BitWidth::U4);
            let threads = 1 + rng.below(5);
            let prefetch = 1 + rng.below(6);
            let (tensors, _) = StreamingDecoder::new(threads, prefetch)
                .decode_model(Arc::new(model))
                .unwrap();
            for (i, (_, w)) in layers.iter().enumerate() {
                assert_eq!(
                    tensors[i].symbols.data(),
                    quantize_mixed(w, BitWidth::U4).symbols.data()
                );
            }
        }
    }
}
