//! Parameter-space segmentation and **parallel Huffman decoding**
//! (paper §III-C, Algorithm 1 `EDGE DEVICE OPERATIONS`).
//!
//! Huffman streams are inherently serial — a symbol's start position is
//! only known once every previous symbol has been decoded. EntroLLM
//! sidesteps this by never concatenating tensors into one stream: the
//! ELM container keeps one byte-aligned segment per weight tensor, so
//! segment boundaries are known *before* decoding and `T` threads can
//! decode disjoint segments with zero synchronization. Since container
//! v3 the same machinery is codec-agnostic: workers fetch each tile's
//! decoder from a shared [`crate::codec::CodecSet`], so a tANS-coded
//! layer rides the identical schedule (tANS streams are just as serial
//! within a tile, and just as independent across tiles).
//!
//! Because per-segment decode times are skewed (different sizes, and
//! skewed symbol mixes make some segments bit-denser than others), the
//! scheduler **shuffles** segments before dealing them round-robin to
//! threads, so each thread receives a balanced mixture (§III-C's
//! "shuffling mechanism"). [`DecodeStats`] exposes per-thread work
//! accounting so the load-balance claim is testable and benchable
//! (ablation bench `ablation_decode`).

mod schedule;
pub mod stream;

pub use schedule::{flat_tiles, Assignment, Strategy};
pub use stream::{
    DecodedLayer, LayerStream, SegmentDecoder, StreamConfig, StreamStats, StreamingDecoder,
};

use crate::codec::CodecSet;
use crate::quant::QuantizedTensor;
use crate::store::ElmModel;
use crate::tensor::TensorU8;
use crate::{Error, Result};
use std::time::{Duration, Instant};

/// Per-thread work accounting from one parallel decode.
#[derive(Debug, Clone, Default)]
pub struct ThreadStats {
    /// Segments this thread decoded.
    pub segments: usize,
    /// Encoded bytes consumed.
    pub encoded_bytes: usize,
    /// Symbols produced.
    pub symbols: usize,
    /// Busy wallclock.
    pub busy: Duration,
}

/// Result accounting for a whole parallel decode.
#[derive(Debug, Clone)]
pub struct DecodeStats {
    /// Wallclock for the whole decode (including thread spawn/join).
    pub wall: Duration,
    /// Per-thread accounting.
    pub threads: Vec<ThreadStats>,
}

impl DecodeStats {
    /// Total symbols decoded.
    pub fn total_symbols(&self) -> usize {
        self.threads.iter().map(|t| t.symbols).sum()
    }

    /// Total encoded bytes consumed.
    pub fn total_encoded_bytes(&self) -> usize {
        self.threads.iter().map(|t| t.encoded_bytes).sum()
    }

    /// Load imbalance: max thread busy-time / mean busy-time. 1.0 is
    /// perfect balance; the §III-C shuffle keeps this near 1.
    pub fn imbalance(&self) -> f64 {
        let busys: Vec<f64> = self.threads.iter().map(|t| t.busy.as_secs_f64()).collect();
        let mean = busys.iter().sum::<f64>() / busys.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        busys.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Work imbalance by *symbols* (deterministic; used on single-core
    /// CI hosts where busy-time is not meaningful).
    pub fn symbol_imbalance(&self) -> f64 {
        let work: Vec<f64> = self.threads.iter().map(|t| t.symbols as f64).collect();
        let mean = work.iter().sum::<f64>() / work.len().max(1) as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        work.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Aggregate decode throughput, symbols/second.
    pub fn symbols_per_sec(&self) -> f64 {
        self.total_symbols() as f64 / self.wall.as_secs_f64().max(1e-12)
    }
}

/// Parallel entropy decoder over an [`ElmModel`] (Huffman or tANS
/// tiles alike).
#[derive(Debug, Clone)]
pub struct ParallelDecoder {
    /// Worker thread count (`T` in Algorithm 1; the paper uses 4 on the
    /// Jetson's quad A57).
    pub threads: usize,
    /// Segment→thread assignment strategy.
    pub strategy: Strategy,
}

impl ParallelDecoder {
    /// Decoder with the paper's shuffled assignment.
    pub fn new(threads: usize) -> Self {
        ParallelDecoder {
            threads: threads.max(1),
            strategy: Strategy::Shuffled { seed: 0x5EED },
        }
    }

    /// Override the assignment strategy (ablation bench).
    pub fn with_strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Decode every layer of `model`, returning tensors in layer order
    /// plus per-thread stats. The unit of assignment is the **tile**
    /// (v2), so a single giant layer is shared by every worker instead
    /// of serializing on one; `ThreadStats::segments` therefore counts
    /// tiles. For a v1 container (one synthesized tile per layer) this
    /// is exactly the classic per-layer schedule.
    pub fn decode_model(&self, model: &ElmModel) -> Result<(Vec<QuantizedTensor>, DecodeStats)> {
        let n = model.layers.len();
        // One codec set for the whole decode: workers look up each
        // tile's decoder by its layer's codec id, so the schedule and
        // the assembly below never branch on the codec.
        let codecs = CodecSet::new(&model.code, model.ans.as_ref())?;
        let (tiles, sizes) = flat_tiles(&model.layers);
        let assignment = self.strategy.assign_sizes(&sizes, self.threads);

        let start = Instant::now();
        // Each worker owns a disjoint set of flat tile indices and fills
        // its own output list; no locks on the decode path.
        type TileOut = Vec<(usize, usize, Vec<u8>)>;
        let results: Vec<Result<(TileOut, ThreadStats)>> = std::thread::scope(|s| {
            let handles: Vec<_> = assignment
                .per_thread
                .iter()
                .map(|indices| {
                    let codecs = &codecs;
                    let tiles = &tiles;
                    let indices = indices.clone();
                    s.spawn(move || {
                        let t0 = Instant::now();
                        let mut out = Vec::with_capacity(indices.len());
                        let mut encoded_bytes = 0usize;
                        let mut symbols = 0usize;
                        for flat in indices {
                            let (layer, t) = tiles[flat];
                            let tile = &model.layers[layer].tiles[t];
                            model.verify_tile(layer, t)?;
                            let mut buf = vec![0u8; tile.n_symbols];
                            codecs
                                .get(model.layers[layer].codec)?
                                .decode_tile(model.tile_bytes(layer, t), &mut buf)?;
                            encoded_bytes += tile.encoded_len;
                            symbols += tile.n_symbols;
                            out.push((layer, t, buf));
                        }
                        let segments = out.len();
                        Ok((
                            out,
                            ThreadStats {
                                segments,
                                encoded_bytes,
                                symbols,
                                busy: t0.elapsed(),
                            },
                        ))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("decode worker panicked")).collect()
        });

        // Assemble: place each decoded tile at its symbol offset within
        // its layer's buffer, then seal layers whose every tile landed.
        let mut bufs: Vec<Vec<u8>> = model.layers.iter().map(|m| vec![0u8; m.n_symbols]).collect();
        let mut missing: Vec<usize> = model.layers.iter().map(|m| m.tiles.len()).collect();
        let mut thread_stats = Vec::with_capacity(results.len());
        for res in results {
            let (decoded, stats) = res?;
            for (layer, t, tile_syms) in decoded {
                let tile = &model.layers[layer].tiles[t];
                bufs[layer][tile.sym_offset..tile.sym_offset + tile.n_symbols]
                    .copy_from_slice(&tile_syms);
                missing[layer] -= 1;
            }
            thread_stats.push(stats);
        }
        let wall = start.elapsed();
        let mut tensors = Vec::with_capacity(n);
        for (i, buf) in bufs.into_iter().enumerate() {
            if missing[i] != 0 {
                return Err(Error::Format(format!("layer {i} never assigned")));
            }
            let meta = &model.layers[i];
            tensors.push(QuantizedTensor {
                symbols: TensorU8::new(meta.shape.clone(), buf)?,
                params: meta.params,
            });
        }
        Ok((
            tensors,
            DecodeStats {
                wall,
                threads: thread_stats,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_mixed, BitWidth};
    use crate::rng::Rng;
    use crate::store::compress;
    use crate::tensor::TensorF32;

    fn model_with_layers(n_layers: usize, seed: u64, bits: BitWidth) -> (Vec<(String, TensorF32)>, ElmModel) {
        let mut rng = Rng::new(seed);
        let layers: Vec<(String, TensorF32)> = (0..n_layers)
            .map(|i| {
                // Skewed sizes so scheduling matters.
                let n = 64 + rng.below(4000) * (1 + i % 3);
                (
                    format!("layer.{i}"),
                    TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                )
            })
            .collect();
        let (model, _) = compress(&layers, bits).unwrap();
        (layers, model)
    }

    #[test]
    fn parallel_equals_serial_decode() {
        let (layers, model) = model_with_layers(17, 0xA, BitWidth::U8);
        for threads in [1, 2, 4, 8] {
            let (tensors, stats) = ParallelDecoder::new(threads).decode_model(&model).unwrap();
            assert_eq!(tensors.len(), layers.len());
            assert_eq!(stats.threads.len(), threads);
            for (i, (_, w)) in layers.iter().enumerate() {
                let direct = quantize_mixed(w, BitWidth::U8);
                assert_eq!(tensors[i].symbols.data(), direct.symbols.data());
            }
        }
    }

    #[test]
    fn parallel_codec_arms_decode_identically() {
        // A tANS container (and a mixed Auto one) must parallel-decode
        // to exactly what the Huffman container decodes to, at any
        // thread count.
        use crate::store::{compress_with_options, CodecChoice};
        let mut rng = Rng::new(0xA45);
        let layers: Vec<(String, TensorF32)> = (0..9)
            .map(|i| {
                let n = 256 + rng.below(5000);
                (
                    format!("layer.{i}"),
                    TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
                )
            })
            .collect();
        let want: Vec<Vec<u8>> = layers
            .iter()
            .map(|(_, w)| quantize_mixed(w, BitWidth::U8).symbols.data().to_vec())
            .collect();
        for choice in [CodecChoice::Huffman, CodecChoice::Ans, CodecChoice::Auto] {
            let (model, _) =
                compress_with_options(&layers, BitWidth::U8, Some(512), choice).unwrap();
            for threads in [1, 4] {
                let (tensors, stats) =
                    ParallelDecoder::new(threads).decode_model(&model).unwrap();
                assert_eq!(stats.total_symbols(), model.n_params());
                for (i, w) in want.iter().enumerate() {
                    assert_eq!(
                        tensors[i].symbols.data(),
                        &w[..],
                        "{choice:?} x{threads}: layer {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn stats_account_for_all_work() {
        let (_, model) = model_with_layers(23, 0xB, BitWidth::U4);
        let (_, stats) = ParallelDecoder::new(4).decode_model(&model).unwrap();
        assert_eq!(stats.total_symbols(), model.n_params());
        assert_eq!(stats.total_encoded_bytes(), model.payload.len());
        // The v2 unit of work is the tile, so `segments` counts tiles.
        let segs: usize = stats.threads.iter().map(|t| t.segments).sum();
        let tiles: usize = model.layers.iter().map(|l| l.tiles.len()).sum();
        assert_eq!(segs, tiles);
        assert!(tiles > model.layers.len(), "fixture must be multi-tile");
    }

    #[test]
    fn single_hot_layer_is_shared_by_all_workers() {
        // The v2 point: one giant layer no longer serializes on one
        // worker — its tiles are dealt across the whole pool.
        let mut rng = Rng::new(0x77);
        let layers = vec![(
            "big".to_string(),
            TensorF32::new(vec![60_000], rng.gaussian_vec(60_000, 0.0, 0.05)).unwrap(),
        )];
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        assert!(model.layers[0].tiles.len() >= 4, "auto tiling must split");
        let (tensors, stats) = ParallelDecoder::new(4).decode_model(&model).unwrap();
        let busy = stats.threads.iter().filter(|t| t.symbols > 0).count();
        assert_eq!(busy, 4, "every worker must decode part of the hot layer");
        assert_eq!(
            tensors[0].symbols.data(),
            quantize_mixed(&layers[0].1, BitWidth::U8).symbols.data()
        );
    }

    #[test]
    fn shuffled_assignment_balances_skewed_segments() {
        // One huge layer + many small: contiguous round-robin of *chunks*
        // would lump the big one with neighbors; shuffling spreads by
        // dealing. Verify symbol imbalance is bounded.
        let mut rng = Rng::new(0xC);
        let mut layers = vec![(
            "big".to_string(),
            TensorF32::new(vec![50_000], rng.gaussian_vec(50_000, 0.0, 0.05)).unwrap(),
        )];
        for i in 0..40 {
            let n = 500 + rng.below(1500);
            layers.push((
                format!("small.{i}"),
                TensorF32::new(vec![n], rng.gaussian_vec(n, 0.0, 0.05)).unwrap(),
            ));
        }
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        let (_, stats) = ParallelDecoder::new(4).decode_model(&model).unwrap();
        // The single 50k layer dominates: perfect balance is impossible,
        // but no thread besides the big-layer one should be starved.
        let min_syms = stats.threads.iter().map(|t| t.symbols).min().unwrap();
        assert!(min_syms > 0, "no thread may be idle");
    }

    #[test]
    fn more_threads_than_segments_is_fine() {
        let (_, model) = model_with_layers(2, 0xD, BitWidth::U8);
        let (tensors, stats) = ParallelDecoder::new(8).decode_model(&model).unwrap();
        assert_eq!(tensors.len(), 2);
        assert_eq!(stats.threads.len(), 8);
        assert_eq!(stats.total_symbols(), model.n_params());
    }

    #[test]
    fn corrupt_segment_fails_cleanly_in_parallel() {
        let (_, mut model) = model_with_layers(8, 0xE, BitWidth::U8);
        let off = model.layers[3].offset;
        model.payload[off] ^= 0xFF;
        let res = ParallelDecoder::new(4).decode_model(&model);
        assert!(res.is_err());
    }

    #[test]
    fn property_any_thread_count_any_strategy_is_lossless() {
        let mut rng = Rng::new(0xF00);
        for _ in 0..10 {
            let n_layers = 1 + rng.below(12);
            let (layers, model) =
                model_with_layers(n_layers, rng.next_u64(), BitWidth::U4);
            let threads = 1 + rng.below(6);
            let strategy = match rng.below(4) {
                0 => Strategy::Shuffled { seed: rng.next_u64() },
                1 => Strategy::Contiguous,
                2 => Strategy::Chunked,
                _ => Strategy::LargestFirst,
            };
            let (tensors, _) = ParallelDecoder::new(threads)
                .with_strategy(strategy)
                .decode_model(&model)
                .unwrap();
            for (i, (_, w)) in layers.iter().enumerate() {
                assert_eq!(
                    tensors[i].symbols.data(),
                    quantize_mixed(w, BitWidth::U4).symbols.data()
                );
            }
        }
    }
}
