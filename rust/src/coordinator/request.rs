//! Request/response types for the serving engine.

use std::time::{Duration, Instant};

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-assigned id (echoed in the response).
    pub id: u64,
    /// Prompt token ids (byte-level).
    pub prompt: Vec<u32>,
    /// Maximum tokens to generate.
    pub max_new_tokens: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Top-k truncation (0 = full distribution).
    pub top_k: usize,
    /// Optional stop token (generation halts after emitting it).
    pub stop_token: Option<u32>,
    /// Enqueue timestamp (set by the engine if `None`-equivalent).
    pub enqueued_at: Option<Instant>,
}

impl Request {
    /// A request with greedy sampling defaults.
    pub fn greedy(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            stop_token: None,
            enqueued_at: None,
        }
    }
}

/// Phase timings for one request (the per-request Table II analogue).
#[derive(Debug, Clone, Default)]
pub struct Timing {
    /// Time spent queued before a slot was free.
    pub queued: Duration,
    /// Prefill wallclock.
    pub prefill: Duration,
    /// Total decode wallclock attributed to this request.
    pub decode: Duration,
    /// Time from admission to first generated token.
    pub first_token: Duration,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Generated token ids (prompt not included).
    pub tokens: Vec<u32>,
    /// Why generation stopped.
    pub finish_reason: FinishReason,
    /// Phase timings.
    pub timing: Timing,
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Emitted the stop token.
    Stop,
    /// Ran out of KV-cache capacity.
    Capacity,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_defaults() {
        let r = Request::greedy(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.top_k, 0);
        assert!(r.stop_token.is_none());
    }
}
