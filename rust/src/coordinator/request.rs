//! Request/response types for the serving engine.

use std::time::{Duration, Instant};

/// Highest priority a request may carry on the wire (inclusive).
pub const PRIORITY_MAX: i32 = 8;
/// Lowest priority a request may carry on the wire (inclusive).
pub const PRIORITY_MIN: i32 = -8;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-assigned id (echoed in the response).
    pub id: u64,
    /// Prompt token ids (byte-level).
    pub prompt: Vec<u32>,
    /// Maximum tokens to generate.
    pub max_new_tokens: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Top-k truncation (0 = full distribution).
    pub top_k: usize,
    /// Optional stop token (generation halts after emitting it).
    pub stop_token: Option<u32>,
    /// Enqueue timestamp (set by the engine if `None`-equivalent).
    pub enqueued_at: Option<Instant>,
    /// Scheduling class: higher runs sooner, may preempt lower. 0 is
    /// the normal interactive class, negatives are batch traffic.
    /// Bounded to `[PRIORITY_MIN, PRIORITY_MAX]` at the protocol edge.
    pub priority: i32,
    /// Deadline relative to `enqueued_at`, covering queue wait **and**
    /// generation. A request still *queued* past its deadline is
    /// answered with an expired error instead of running dead work; a
    /// request already *generating* is stopped at the next engine step
    /// and answered with the prefix it had produced. (Preemption
    /// restarts `enqueued_at`, so a checkpointed victim's deadline
    /// clock restarts with its re-queued wait.)
    pub deadline: Option<Duration>,
    /// Checkpoint of a preempted generation; `None` for fresh
    /// requests. Boxed: the common path should not pay its size.
    pub resume: Option<Box<ResumeState>>,
}

impl Request {
    /// A request with greedy sampling defaults.
    pub fn greedy(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
            top_k: 0,
            stop_token: None,
            enqueued_at: None,
            priority: 0,
            deadline: None,
            resume: None,
        }
    }

    /// Builder-style priority override.
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Builder-style deadline override.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Everything a preempted generation needs to continue bit-identically
/// after re-admission: the tokens already emitted, the sequence
/// position, and (for KV-stateful backends) the slot's extracted cache.
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Tokens generated before preemption (prompt not included).
    pub generated: Vec<u32>,
    /// Sequence position the next decode step writes at.
    pub pos: usize,
    /// Last emitted token (the next decode step's input).
    pub last: u32,
    /// Extracted KV state (`None` for stateless digest backends).
    pub kv: Option<(Vec<f32>, Vec<f32>)>,
    /// Timings accumulated before preemption; the resumed run adds to
    /// them so the response reports whole-request phase costs.
    pub timing: Timing,
}

/// Phase timings for one request (the per-request Table II analogue).
#[derive(Debug, Clone, Default)]
pub struct Timing {
    /// Time spent queued before a slot was free.
    pub queued: Duration,
    /// Prefill wallclock.
    pub prefill: Duration,
    /// Total decode wallclock attributed to this request.
    pub decode: Duration,
    /// Time from admission to first generated token.
    pub first_token: Duration,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct Response {
    /// Request id.
    pub id: u64,
    /// Generated token ids (prompt not included).
    pub tokens: Vec<u32>,
    /// Why generation stopped.
    pub finish_reason: FinishReason,
    /// Phase timings.
    pub timing: Timing,
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit `max_new_tokens`.
    Length,
    /// Emitted the stop token.
    Stop,
    /// Ran out of KV-cache capacity.
    Capacity,
    /// Deadline passed — while still queued (tokens are then an empty
    /// or preempted prefix) or mid-generation (tokens are the prefix
    /// generated before the engine stopped it).
    Expired,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_defaults() {
        let r = Request::greedy(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.top_k, 0);
        assert!(r.stop_token.is_none());
        assert_eq!(r.priority, 0);
        assert!(r.deadline.is_none());
        assert!(r.resume.is_none());
    }

    #[test]
    fn builders_set_class_fields() {
        let r = Request::greedy(1, vec![1], 4)
            .with_priority(-3)
            .with_deadline(Duration::from_millis(250));
        assert_eq!(r.priority, -3);
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
    }
}
