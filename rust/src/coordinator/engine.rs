//! The generation engine: continuous batching over fixed decode slots.
//!
//! Loop shape (one [`Engine::step`]):
//!
//! 1. **Admit** — while a slot is free and the queue is non-empty:
//!    prefill the next request (B=1 executable), sample its first token
//!    from the prefill logits, splice its KV into the free slot.
//! 2. **Decode** — one batched decode step advances every active slot
//!    (idle slots run with a harmless pad token; their lanes are
//!    ignored).
//! 3. **Sample & retire** — per-slot sampling; sequences that hit their
//!    token budget, stop token, or KV capacity produce a [`Response`]
//!    and free their slot for the next admission — the "continuous"
//!    part of continuous batching.

use super::backend::Backend;
use super::batcher::{AdmissionQueue, QueueStats};
use super::request::{FinishReason, Request, Response, Timing};
use super::sampler::{SampleCfg, Sampler};
use crate::metrics::LatencyHistogram;
use crate::Result;
use std::time::Instant;

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Admission queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Sampler seed (generation is deterministic given request order).
    pub sample_seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            queue_capacity: 256,
            sample_seed: 0xE47,
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Completed requests.
    pub completed: u64,
    /// Generated tokens across all requests.
    pub tokens: u64,
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Sum over decode steps of active-slot count (occupancy).
    pub occupancy_sum: u64,
    /// Requests cancelled before completion (dead waiters, shutdown
    /// drain).
    pub cancelled: u64,
    /// Prefill latency distribution.
    pub prefill_lat: LatencyHistogram,
    /// Per-step decode latency distribution.
    pub decode_lat: LatencyHistogram,
    /// First-token latency distribution (admission → first token).
    pub first_token_lat: LatencyHistogram,
}

impl EngineStats {
    /// Mean active slots per decode step.
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.decode_steps as f64
        }
    }
}

struct Active {
    req: Request,
    generated: Vec<u32>,
    /// Next KV write position (= prompt_len + generated count).
    pos: usize,
    /// Token to feed the next decode step.
    last: u32,
    timing: Timing,
}

/// The serving engine. Generic over [`Backend`] (PJRT in production,
/// mock in tests).
pub struct Engine<B: Backend> {
    backend: B,
    queue: AdmissionQueue,
    slots: Vec<Option<Active>>,
    sampler: Sampler,
    stats: EngineStats,
}

impl<B: Backend> Engine<B> {
    /// New engine over a backend.
    pub fn new(backend: B, cfg: EngineConfig) -> Self {
        let slots = (0..backend.cfg().batch).map(|_| None).collect();
        Engine {
            backend,
            queue: AdmissionQueue::new(cfg.queue_capacity),
            slots,
            sampler: Sampler::new(cfg.sample_seed),
            stats: EngineStats::default(),
        }
    }

    /// Submit a request (errors on backpressure).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.queue.push(req)
    }

    /// Cancel a request by id: drop it from the admission queue or
    /// free its batch slot (the generation's partial output is
    /// discarded — there is nobody left to read it). Returns whether
    /// anything was cancelled.
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.queue.remove(id).is_some() {
            self.stats.cancelled += 1;
            return true;
        }
        for slot in self.slots.iter_mut() {
            if slot.as_ref().is_some_and(|a| a.req.id == id) {
                *slot = None;
                self.stats.cancelled += 1;
                return true;
            }
        }
        false
    }

    /// Pending + active work?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Active slot count.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Queue statistics.
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Borrow the backend (eval tooling).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Weight-residency cache counters, when the backend faults weights
    /// through one (`None` for fully-resident backends) — the
    /// observability hook the `{"stats":true}` admin line surfaces.
    pub fn residency(&self) -> Option<crate::residency::CacheCounters> {
        self.backend.residency()
    }

    /// Decode-ahead prefetch counters, when the backend overlaps layer
    /// decode with token compute (`None` otherwise) — the `prefetch_*`
    /// half of the `{"stats":true}` admin line.
    pub fn prefetch(&self) -> Option<crate::residency::PrefetchCounters> {
        self.backend.prefetch()
    }

    fn sample_cfg(req: &Request) -> SampleCfg {
        SampleCfg {
            temperature: req.temperature,
            top_k: req.top_k,
        }
    }

    /// Admit requests into free slots. Returns responses for requests
    /// that finish during admission (e.g. max_new_tokens == 1).
    fn admit(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            let Some(req) = self.queue.pop() else { break };
            let admitted = Instant::now();
            let queued = req
                .enqueued_at
                .map(|t| admitted.duration_since(t))
                .unwrap_or_default();

            let t0 = Instant::now();
            let prompt_cap = self.backend.cfg().prefill_len;
            let prompt_len = req.prompt.len().min(prompt_cap).max(1);
            let (logits, k1, v1) = self.backend.prefill(&req.prompt)?;
            self.backend.set_slot(slot, &k1, &v1)?;
            let prefill = t0.elapsed();
            self.stats.prefill_lat.record(prefill);

            let first = self.sampler.sample(&logits, Self::sample_cfg(&req));
            let first_token = admitted.elapsed() + queued;
            self.stats.first_token_lat.record(first_token);

            let act = Active {
                timing: Timing {
                    queued,
                    prefill,
                    decode: Default::default(),
                    first_token,
                },
                req,
                generated: vec![first],
                pos: prompt_len,
                last: first,
            };
            if let Some(reason) = self.finish_reason(&act) {
                done.push(self.retire(act, reason));
            } else {
                self.slots[slot] = Some(act);
            }
        }
        Ok(done)
    }

    fn finish_reason(&self, a: &Active) -> Option<FinishReason> {
        if a.generated.len() >= a.req.max_new_tokens {
            return Some(FinishReason::Length);
        }
        if a.req.stop_token == Some(a.last) {
            return Some(FinishReason::Stop);
        }
        if a.pos + 1 >= self.backend.cfg().max_seq {
            return Some(FinishReason::Capacity);
        }
        None
    }

    fn retire(&mut self, a: Active, reason: FinishReason) -> Response {
        self.stats.completed += 1;
        self.stats.tokens += a.generated.len() as u64;
        Response {
            id: a.req.id,
            tokens: a.generated,
            finish_reason: reason,
            timing: a.timing,
        }
    }

    /// One engine step: admit + one batched decode. Returns any
    /// responses completed during this step.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = self.admit()?;
        let active = self.active();
        if active == 0 {
            return Ok(done);
        }

        let b = self.backend.cfg().batch;
        let mut tokens = vec![0u32; b];
        let mut pos = vec![0u32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(a) = s {
                tokens[i] = a.last;
                pos[i] = a.pos as u32;
            }
        }
        let t0 = Instant::now();
        let logits = self.backend.decode(&tokens, &pos)?;
        let step_time = t0.elapsed();
        self.stats.decode_lat.record(step_time);
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += active as u64;

        let vocab = self.backend.cfg().vocab;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Some(a) = slot.as_mut() else { continue };
            let row = &logits[i * vocab..(i + 1) * vocab];
            let cfg = SampleCfg {
                temperature: a.req.temperature,
                top_k: a.req.top_k,
            };
            let tok = self.sampler.sample(row, cfg);
            a.generated.push(tok);
            a.last = tok;
            a.pos += 1;
            a.timing.decode += step_time;
        }
        // Retire finished sequences (borrow dance: take out, decide).
        for i in 0..self.slots.len() {
            if let Some(a) = self.slots[i].take() {
                if let Some(reason) = self.finish_reason(&a) {
                    done.push(self.retire(a, reason));
                } else {
                    self.slots[i] = Some(a);
                }
            }
        }
        Ok(done)
    }

    /// Drive until queue and slots drain (or `max_steps` elapse).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            out.extend(self.step()?);
            steps += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::MockBackend;
    use super::*;

    fn engine(batch: usize) -> Engine<MockBackend> {
        Engine::new(MockBackend::new(batch, 32, 64), EngineConfig::default())
    }

    #[test]
    fn single_request_generates_exact_budget() {
        let mut e = engine(2);
        e.submit(Request::greedy(1, vec![5, 6], 4)).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 1);
        assert_eq!(rs[0].tokens.len(), 4);
        assert_eq!(rs[0].finish_reason, FinishReason::Length);
        // Mock chain: first = (5+6+1)%64=12, then +slot+1 per step (slot 0).
        assert_eq!(rs[0].tokens, vec![12, 13, 14, 15]);
    }

    #[test]
    fn batch_processes_more_requests_than_slots() {
        let mut e = engine(2);
        for id in 0..7 {
            e.submit(Request::greedy(id, vec![id as u32], 3)).unwrap();
        }
        let rs = e.run_to_completion(1000).unwrap();
        assert_eq!(rs.len(), 7);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        for r in &rs {
            assert_eq!(r.tokens.len(), 3);
        }
        // Continuous batching must refill: with 2 slots and 7 requests,
        // decode steps < 7 * 2 (serial would be ~14).
        assert!(e.stats().decode_steps < 14, "steps {}", e.stats().decode_steps);
        assert!(e.stats().mean_occupancy() > 1.0);
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = engine(1);
        // Mock: first token = (2+1)%64 = 3; then 4, 5, ...
        let mut r = Request::greedy(9, vec![2], 100);
        r.stop_token = Some(5);
        e.submit(r).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs[0].finish_reason, FinishReason::Stop);
        assert_eq!(rs[0].tokens, vec![3, 4, 5]);
    }

    #[test]
    fn capacity_bound_respected() {
        let mut e = engine(1); // max_seq 32, prefill_len 16
        let prompt: Vec<u32> = (0..16).collect();
        e.submit(Request::greedy(3, prompt, 10_000)).unwrap();
        let rs = e.run_to_completion(10_000).unwrap();
        assert_eq!(rs[0].finish_reason, FinishReason::Capacity);
        // pos starts at 16, finishes when pos+1 >= 32 → 15 generated+1 first.
        assert!(rs[0].tokens.len() <= 16);
        assert!(!rs[0].tokens.is_empty());
    }

    #[test]
    fn one_token_requests_never_enter_decode() {
        let mut e = engine(2);
        e.submit(Request::greedy(1, vec![1], 1)).unwrap();
        e.submit(Request::greedy(2, vec![2], 1)).unwrap();
        let rs = e.run_to_completion(10).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(e.stats().decode_steps, 0);
        assert!(rs.iter().all(|r| r.tokens.len() == 1));
    }

    #[test]
    fn timing_fields_populated() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 3)).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        let t = &rs[0].timing;
        assert!(t.first_token >= t.prefill);
        assert!(t.decode > std::time::Duration::ZERO);
    }

    #[test]
    fn queue_backpressure_propagates() {
        let mut e = Engine::new(
            MockBackend::new(1, 32, 64),
            EngineConfig {
                queue_capacity: 2,
                sample_seed: 0,
            },
        );
        e.submit(Request::greedy(1, vec![1], 2)).unwrap();
        e.submit(Request::greedy(2, vec![1], 2)).unwrap();
        assert!(e.submit(Request::greedy(3, vec![1], 2)).is_err());
    }

    #[test]
    fn cancel_removes_a_queued_request() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 4)).unwrap();
        e.submit(Request::greedy(2, vec![2], 4)).unwrap();
        assert!(e.cancel(2), "queued request must be cancellable");
        assert_eq!(e.stats().cancelled, 1);
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 1, "only the surviving request completes");
    }

    #[test]
    fn cancel_frees_an_active_slot_for_the_next_admission() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 100)).unwrap();
        e.step().unwrap(); // admit into the only slot, start generating
        assert_eq!(e.active(), 1);
        assert!(e.cancel(1), "active request must be cancellable");
        assert_eq!(e.active(), 0, "cancel must free the batch slot");
        assert_eq!(e.stats().cancelled, 1);
        // The freed slot admits and completes the next request.
        e.submit(Request::greedy(2, vec![2], 3)).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 2);
        assert_eq!(rs[0].tokens.len(), 3);
    }

    #[test]
    fn cancel_unknown_id_is_a_no_op() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 2)).unwrap();
        assert!(!e.cancel(99));
        assert_eq!(e.stats().cancelled, 0);
        assert_eq!(e.run_to_completion(100).unwrap().len(), 1);
    }

    #[test]
    fn stats_account_tokens() {
        let mut e = engine(2);
        for id in 0..4 {
            e.submit(Request::greedy(id, vec![1], 5)).unwrap();
        }
        let rs = e.run_to_completion(1000).unwrap();
        let total: usize = rs.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(e.stats().tokens as usize, total);
        assert_eq!(e.stats().completed, 4);
    }
}
