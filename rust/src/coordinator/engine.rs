//! The generation engine: continuous batching over fixed decode slots.
//!
//! Loop shape (one [`Engine::step`]):
//!
//! 1. **Admit** — while a slot is free and the queue is non-empty:
//!    prefill the next request (B=1 executable), sample its first token
//!    from the prefill logits, splice its KV into the free slot.
//! 2. **Decode** — one batched decode step advances every active slot
//!    (idle slots run with a harmless pad token; their lanes are
//!    ignored).
//! 3. **Sample & retire** — per-slot sampling; sequences that hit their
//!    token budget, stop token, or KV capacity produce a [`Response`]
//!    and free their slot for the next admission — the "continuous"
//!    part of continuous batching.

use super::backend::Backend;
use super::batcher::{AdmissionQueue, QueueStats};
use super::request::{FinishReason, Request, Response, ResumeState, Timing};
use super::sampler::{SampleCfg, Sampler};
use super::speculative::{accept_longest_prefix, SpecStats};
use crate::metrics::LatencyHistogram;
use crate::Result;
use std::time::{Duration, Instant};

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Admission queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Sampler seed (generation is deterministic given request order).
    pub sample_seed: u64,
    /// Preempt the lowest-class in-flight generation when a strictly
    /// higher-class request waits and the batch is full. The preempted
    /// request re-queues at the front of its class with its generated
    /// prefix (KV extracted via [`Backend::take_slot`]) and resumes
    /// bit-identically.
    pub preemption: bool,
    /// Queue aging interval: each elapsed interval a waiting request's
    /// *effective* priority rises one class (dequeue order only — never
    /// preemption decisions). `None` disables aging.
    pub aging: Option<Duration>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            queue_capacity: 256,
            sample_seed: 0xE47,
            preemption: true,
            aging: Some(Duration::from_millis(1000)),
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Completed requests.
    pub completed: u64,
    /// Generated tokens across all requests.
    pub tokens: u64,
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Sum over decode steps of active-slot count (occupancy).
    pub occupancy_sum: u64,
    /// Requests cancelled before completion (dead waiters, shutdown
    /// drain).
    pub cancelled: u64,
    /// In-flight generations preempted by a higher-class request.
    pub preemptions: u64,
    /// Queued requests answered as expired (deadline passed waiting).
    pub expired: u64,
    /// Prefill latency distribution.
    pub prefill_lat: LatencyHistogram,
    /// Per-step decode latency distribution.
    pub decode_lat: LatencyHistogram,
    /// First-token latency distribution (admission → first token).
    pub first_token_lat: LatencyHistogram,
}

impl EngineStats {
    /// Mean active slots per decode step.
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.decode_steps as f64
        }
    }
}

struct Active {
    req: Request,
    generated: Vec<u32>,
    /// Next KV write position (= prompt_len + generated count).
    pos: usize,
    /// Token to feed the next decode step.
    last: u32,
    timing: Timing,
}

/// The serving engine. Generic over [`Backend`] (PJRT in production,
/// mock in tests).
pub struct Engine<B: Backend> {
    backend: B,
    queue: AdmissionQueue,
    slots: Vec<Option<Active>>,
    sampler: Sampler,
    stats: EngineStats,
    preemption: bool,
}

impl<B: Backend> Engine<B> {
    /// New engine over a backend.
    pub fn new(backend: B, cfg: EngineConfig) -> Self {
        let slots = (0..backend.cfg().batch).map(|_| None).collect();
        let mut queue = AdmissionQueue::new(cfg.queue_capacity);
        queue.set_aging(cfg.aging);
        Engine {
            backend,
            queue,
            slots,
            sampler: Sampler::new(cfg.sample_seed),
            stats: EngineStats::default(),
            preemption: cfg.preemption,
        }
    }

    /// Submit a request (errors on backpressure).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.queue.push(req)
    }

    /// Cancel a request by id: drop it from the admission queue or
    /// free its batch slot (the generation's partial output is
    /// discarded — there is nobody left to read it). Returns whether
    /// anything was cancelled.
    pub fn cancel(&mut self, id: u64) -> bool {
        if self.queue.remove(id).is_some() {
            self.stats.cancelled += 1;
            return true;
        }
        for slot in self.slots.iter_mut() {
            if slot.as_ref().is_some_and(|a| a.req.id == id) {
                *slot = None;
                self.stats.cancelled += 1;
                return true;
            }
        }
        false
    }

    /// Pending + active work?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Active slot count.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Queue statistics.
    pub fn queue_stats(&self) -> QueueStats {
        self.queue.stats()
    }

    /// Borrow the backend (eval tooling).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutably borrow the backend. The multi-model coordinator uses
    /// this to drive one model's backend as the *draft* proposer while
    /// another model's engine runs the speculative verify step
    /// ([`Engine::step_speculative`]).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Weight-residency cache counters, when the backend faults weights
    /// through one (`None` for fully-resident backends) — the
    /// observability hook the `{"stats":true}` admin line surfaces.
    pub fn residency(&self) -> Option<crate::residency::CacheCounters> {
        self.backend.residency()
    }

    /// Decode-ahead prefetch counters, when the backend overlaps layer
    /// decode with token compute (`None` otherwise) — the `prefetch_*`
    /// half of the `{"stats":true}` admin line.
    pub fn prefetch(&self) -> Option<crate::residency::PrefetchCounters> {
        self.backend.prefetch()
    }

    fn sample_cfg(req: &Request) -> SampleCfg {
        SampleCfg {
            temperature: req.temperature,
            top_k: req.top_k,
        }
    }

    /// Admit one request into the (free) slot `slot`. Fresh requests
    /// prefill and sample their first token; preempted requests resume
    /// from their [`ResumeState`] — KV re-spliced if the backend
    /// carries any, no prefill, no sampler draw (the prefix already
    /// consumed its draws). Returns a response if the request finishes
    /// during admission (e.g. `max_new_tokens == 1`).
    fn admit_one(&mut self, slot: usize, mut req: Request) -> Result<Option<Response>> {
        let admitted = Instant::now();
        let queued = req
            .enqueued_at
            .map(|t| admitted.duration_since(t))
            .unwrap_or_default();

        let act = if let Some(state) = req.resume.take() {
            let state = *state;
            if let Some((k1, v1)) = &state.kv {
                self.backend.set_slot(slot, k1, v1)?;
            }
            let mut timing = state.timing;
            timing.queued += queued;
            Active {
                timing,
                req,
                generated: state.generated,
                pos: state.pos,
                last: state.last,
            }
        } else {
            let t0 = Instant::now();
            let prompt_cap = self.backend.cfg().prefill_len;
            let prompt_len = req.prompt.len().min(prompt_cap).max(1);
            let (logits, k1, v1) = self.backend.prefill(&req.prompt)?;
            self.backend.set_slot(slot, &k1, &v1)?;
            let prefill = t0.elapsed();
            self.stats.prefill_lat.record(prefill);

            let first = self.sampler.sample(&logits, Self::sample_cfg(&req));
            let first_token = admitted.elapsed() + queued;
            self.stats.first_token_lat.record(first_token);

            Active {
                timing: Timing {
                    queued,
                    prefill,
                    decode: Default::default(),
                    first_token,
                },
                req,
                generated: vec![first],
                pos: prompt_len,
                last: first,
            }
        };
        if let Some(reason) = self.finish_reason(&act) {
            Ok(Some(self.retire(act, reason)))
        } else {
            self.slots[slot] = Some(act);
            Ok(None)
        }
    }

    /// Admit requests into free slots. Returns responses for requests
    /// that finish during admission (e.g. max_new_tokens == 1).
    fn admit(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        for slot in 0..self.slots.len() {
            if self.slots[slot].is_some() {
                continue;
            }
            let Some(req) = self.queue.pop() else { break };
            if let Some(resp) = self.admit_one(slot, req)? {
                done.push(resp);
            }
        }
        Ok(done)
    }

    /// While a strictly higher-class request heads the queue and the
    /// batch is full, preempt the lowest-class in-flight generation:
    /// extract its KV state, checkpoint its generated prefix, re-queue
    /// it at the front of its class, and admit the waiting request into
    /// the freed slot. Decisions compare *static* classes (aging never
    /// promotes anyone into preempting), the tie-break victims the
    /// longest remaining generation, and the strict `<` comparison
    /// makes equal-class thrash impossible. Each iteration dispatches
    /// one queued request, so the loop terminates.
    fn preempt(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        loop {
            let Some(head_class) = self.queue.peek().map(|r| r.priority) else {
                break;
            };
            // A slot freed mid-loop (an admitted request retiring
            // instantly) is plain-admitted into, never preempted for.
            if let Some(free) = self.slots.iter().position(|s| s.is_none()) {
                let head = self.queue.pop().expect("peeked above");
                if let Some(resp) = self.admit_one(free, head)? {
                    done.push(resp);
                }
                continue;
            }
            // Lowest static class among active slots; ties prefer the
            // generation with the most tokens still to go.
            let mut victim: Option<(usize, i32, usize)> = None;
            for (i, s) in self.slots.iter().enumerate() {
                let Some(a) = s else { continue };
                let remaining = a.req.max_new_tokens.saturating_sub(a.generated.len());
                let better = match victim {
                    None => true,
                    Some((_, vp, vr)) => {
                        a.req.priority < vp || (a.req.priority == vp && remaining > vr)
                    }
                };
                if better {
                    victim = Some((i, a.req.priority, remaining));
                }
            }
            let Some((slot, victim_class, _)) = victim else { break };
            if victim_class >= head_class {
                break;
            }

            let a = self.slots[slot].take().expect("victim is active");
            let kv = self.backend.take_slot(slot)?;
            let mut req = a.req;
            // Queue-wait accounting restarts now; the wait already paid
            // is preserved inside the checkpointed timing.
            req.enqueued_at = Some(Instant::now());
            req.resume = Some(Box::new(ResumeState {
                generated: a.generated,
                pos: a.pos,
                last: a.last,
                kv,
                timing: a.timing,
            }));
            self.queue.push_front(req);
            self.stats.preemptions += 1;

            let head = self.queue.pop().expect("queue was non-empty");
            if let Some(resp) = self.admit_one(slot, head)? {
                done.push(resp);
            }
        }
        Ok(done)
    }

    /// Answer every queued request whose deadline passed while it
    /// waited with a [`FinishReason::Expired`] response instead of
    /// running dead work. A preempted-then-expired request reports its
    /// generated prefix.
    fn expire_queued(&mut self) -> Vec<Response> {
        let now = Instant::now();
        self.queue
            .expire(now)
            .into_iter()
            .map(|mut r| {
                self.stats.expired += 1;
                let (tokens, timing) = match r.resume.take() {
                    Some(state) => (state.generated, state.timing),
                    None => (Vec::new(), Timing::default()),
                };
                Response {
                    id: r.id,
                    tokens,
                    finish_reason: FinishReason::Expired,
                    timing,
                }
            })
            .collect()
    }

    /// Stop every *running* generation whose deadline has passed: the
    /// slot is freed and the request is answered with the prefix it had
    /// generated, marked [`FinishReason::Expired`]. Together with
    /// [`Engine::expire_queued`] this makes `deadline_ms` a bound on
    /// **total** time since enqueue, not just queue wait — a caller who
    /// stopped waiting at its deadline no longer keeps a batch slot
    /// burning on an answer nobody reads.
    fn expire_running(&mut self) -> Vec<Response> {
        let now = Instant::now();
        let mut done = Vec::new();
        for slot in self.slots.iter_mut() {
            let expired = slot.as_ref().is_some_and(|a| {
                match (a.req.deadline, a.req.enqueued_at) {
                    (Some(d), Some(t0)) => now.saturating_duration_since(t0) > d,
                    _ => false,
                }
            });
            if expired {
                let a = slot.take().expect("checked above");
                self.stats.expired += 1;
                done.push(Response {
                    id: a.req.id,
                    tokens: a.generated,
                    finish_reason: FinishReason::Expired,
                    timing: a.timing,
                });
            }
        }
        done
    }

    fn finish_reason(&self, a: &Active) -> Option<FinishReason> {
        if a.generated.len() >= a.req.max_new_tokens {
            return Some(FinishReason::Length);
        }
        if a.req.stop_token == Some(a.last) {
            return Some(FinishReason::Stop);
        }
        if a.pos + 1 >= self.backend.cfg().max_seq {
            return Some(FinishReason::Capacity);
        }
        None
    }

    fn retire(&mut self, a: Active, reason: FinishReason) -> Response {
        self.stats.completed += 1;
        self.stats.tokens += a.generated.len() as u64;
        Response {
            id: a.req.id,
            tokens: a.generated,
            finish_reason: reason,
            timing: a.timing,
        }
    }

    /// Scheduling phase shared by [`Engine::step`] and
    /// [`Engine::step_speculative`]: expire (queued *and* running),
    /// admit, preempt.
    fn pre_step(&mut self) -> Result<Vec<Response>> {
        let mut done = self.expire_queued();
        done.extend(self.expire_running());
        done.extend(self.admit()?);
        if self.preemption && !self.queue.is_empty() {
            done.extend(self.preempt()?);
        }
        Ok(done)
    }

    /// One plain batched decode over the active slots: decode, sample
    /// per slot, retire finished sequences.
    fn decode_once(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();
        let active = self.active();
        if active == 0 {
            return Ok(done);
        }

        let b = self.backend.cfg().batch;
        let mut tokens = vec![0u32; b];
        let mut pos = vec![0u32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(a) = s {
                tokens[i] = a.last;
                pos[i] = a.pos as u32;
            }
        }
        let t0 = Instant::now();
        let logits = self.backend.decode(&tokens, &pos)?;
        let step_time = t0.elapsed();
        self.stats.decode_lat.record(step_time);
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += active as u64;

        let vocab = self.backend.cfg().vocab;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Some(a) = slot.as_mut() else { continue };
            let row = &logits[i * vocab..(i + 1) * vocab];
            let cfg = SampleCfg {
                temperature: a.req.temperature,
                top_k: a.req.top_k,
            };
            let tok = self.sampler.sample(row, cfg);
            a.generated.push(tok);
            a.last = tok;
            a.pos += 1;
            a.timing.decode += step_time;
        }
        // Retire finished sequences (borrow dance: take out, decide).
        for i in 0..self.slots.len() {
            if let Some(a) = self.slots[i].take() {
                if let Some(reason) = self.finish_reason(&a) {
                    done.push(self.retire(a, reason));
                } else {
                    self.slots[i] = Some(a);
                }
            }
        }
        Ok(done)
    }

    /// One engine step: expire + admit (+ preempt) + one batched
    /// decode. Returns any responses completed during this step.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = self.pre_step()?;
        done.extend(self.decode_once()?);
        Ok(done)
    }

    /// One **speculative** engine step: the scheduling phase of
    /// [`Engine::step`], then — instead of one plain decode — `draft`
    /// proposes up to `k` greedy tokens per active slot and this
    /// engine's (target) backend verifies every proposal block in
    /// batched [`Backend::argmax_rows`] calls. Acceptance is the
    /// longest-matching-prefix walk of
    /// [`crate::coordinator::speculative`]: the emitted stream is
    /// bit-identical to what plain [`Engine::step`]s would have
    /// produced, but a step can emit up to `k + 1` tokens per slot.
    ///
    /// Falls back to one plain decode (counted in
    /// [`SpecStats::fallback_steps`]) when any active request samples
    /// (`temperature > 0` — speculation is greedy-only, and greedy
    /// sampling never draws from the RNG, so mixing speculative and
    /// plain steps cannot drift sampler state) or when either backend
    /// declines stateless verification ([`Backend::argmax_rows`]
    /// returning `None`).
    ///
    /// Preemption interacts coherently: proposals are ephemeral within
    /// one step, so a checkpoint taken between steps ([`ResumeState`])
    /// never contains speculative state — a preempted request resumes
    /// bit-identically whether either run speculated or not.
    pub fn step_speculative<D: Backend>(
        &mut self,
        draft: &mut D,
        k: usize,
        spec: &mut SpecStats,
    ) -> Result<Vec<Response>> {
        let mut done = self.pre_step()?;
        let active = self.active();
        if active == 0 {
            return Ok(done);
        }
        let all_greedy = self
            .slots
            .iter()
            .flatten()
            .all(|a| a.req.temperature <= 0.0);
        if !all_greedy {
            spec.fallback_steps += 1;
            done.extend(self.decode_once()?);
            return Ok(done);
        }

        let max_seq = self.backend.cfg().max_seq;
        // Per-slot proposal depth: never propose past the KV capacity
        // (verify rows sit at positions P .. P+kᵢ, all < max_seq) or
        // past the request's remaining token budget (kᵢ + 1 emitted
        // tokens at most).
        let plans: Vec<(usize, usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                s.as_ref().map(|a| {
                    let cap = (max_seq - 1).saturating_sub(a.pos);
                    let rem = a.req.max_new_tokens.saturating_sub(a.generated.len());
                    (i, k.min(cap).min(rem.saturating_sub(1)))
                })
            })
            .collect();

        let t0 = Instant::now();

        // Draft proposal chains, advanced one token per batched round:
        // round j extends every slot whose depth exceeds j.
        let mut proposals: Vec<Vec<u32>> = vec![Vec::new(); plans.len()];
        let max_k = plans.iter().map(|&(_, ki)| ki).max().unwrap_or(0);
        let draft_batch = draft.cfg().batch.max(1);
        for round in 0..max_k {
            let mut lanes: Vec<usize> = Vec::new();
            let mut toks: Vec<u32> = Vec::new();
            let mut pos: Vec<u32> = Vec::new();
            for (pi, &(slot, ki)) in plans.iter().enumerate() {
                if round < ki {
                    let a = self.slots[slot].as_ref().expect("planned slot is active");
                    let tail = proposals[pi].last().copied().unwrap_or(a.last);
                    lanes.push(pi);
                    toks.push(tail);
                    pos.push((a.pos + round) as u32);
                }
            }
            if lanes.is_empty() {
                break;
            }
            let mut verdicts: Vec<u32> = Vec::with_capacity(lanes.len());
            for chunk in 0..lanes.len().div_ceil(draft_batch) {
                let lo = chunk * draft_batch;
                let hi = (lo + draft_batch).min(lanes.len());
                match draft.argmax_rows(&toks[lo..hi], &pos[lo..hi])? {
                    Some(v) => verdicts.extend(v),
                    None => {
                        // Draft cannot verify detached rows: no
                        // speculation possible with this pairing.
                        spec.fallback_steps += 1;
                        done.extend(self.decode_once()?);
                        return Ok(done);
                    }
                }
            }
            for (&pi, &tok) in lanes.iter().zip(&verdicts) {
                proposals[pi].push(tok);
            }
        }
        spec.proposed += proposals.iter().map(|p| p.len() as u64).sum::<u64>();

        // Target verification: one row block of kᵢ + 1 rows per slot,
        // chunked to the target's batch width.
        let mut vtoks: Vec<u32> = Vec::new();
        let mut vpos: Vec<u32> = Vec::new();
        for (pi, &(slot, _)) in plans.iter().enumerate() {
            let a = self.slots[slot].as_ref().expect("planned slot is active");
            vtoks.push(a.last);
            vpos.push(a.pos as u32);
            for (j, &d) in proposals[pi].iter().enumerate() {
                vtoks.push(d);
                vpos.push((a.pos + j + 1) as u32);
            }
        }
        let target_batch = self.backend.cfg().batch.max(1);
        let mut verdicts: Vec<u32> = Vec::with_capacity(vtoks.len());
        for chunk in 0..vtoks.len().div_ceil(target_batch) {
            let lo = chunk * target_batch;
            let hi = (lo + target_batch).min(vtoks.len());
            match self.backend.argmax_rows(&vtoks[lo..hi], &vpos[lo..hi])? {
                Some(v) => verdicts.extend(v),
                None => {
                    spec.fallback_steps += 1;
                    done.extend(self.decode_once()?);
                    return Ok(done);
                }
            }
        }

        let step_time = t0.elapsed();
        self.stats.decode_lat.record(step_time);
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += active as u64;
        spec.steps += 1;

        // Acceptance + emission, one token at a time so every finish
        // condition truncates at exactly the token target-only decode
        // would have stopped at.
        let mut off = 0usize;
        for (pi, &(slot, _)) in plans.iter().enumerate() {
            let block = &verdicts[off..off + proposals[pi].len() + 1];
            off += proposals[pi].len() + 1;
            let emit = accept_longest_prefix(&proposals[pi], block);
            spec.accepted += (emit.len() - 1) as u64;
            let mut a = self.slots[slot].take().expect("planned slot is active");
            a.timing.decode += step_time;
            let mut finished = None;
            for tok in emit {
                a.generated.push(tok);
                a.last = tok;
                a.pos += 1;
                spec.emitted += 1;
                if let Some(reason) = self.finish_reason(&a) {
                    finished = Some(reason);
                    break;
                }
            }
            match finished {
                Some(reason) => done.push(self.retire(a, reason)),
                None => self.slots[slot] = Some(a),
            }
        }
        Ok(done)
    }

    /// Drive until queue and slots drain (or `max_steps` elapse).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut steps = 0;
        while self.has_work() && steps < max_steps {
            out.extend(self.step()?);
            steps += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::{DigestBackend, MockBackend};
    use super::*;

    fn engine(batch: usize) -> Engine<MockBackend> {
        Engine::new(MockBackend::new(batch, 32, 64), EngineConfig::default())
    }

    #[test]
    fn single_request_generates_exact_budget() {
        let mut e = engine(2);
        e.submit(Request::greedy(1, vec![5, 6], 4)).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 1);
        assert_eq!(rs[0].tokens.len(), 4);
        assert_eq!(rs[0].finish_reason, FinishReason::Length);
        // Mock chain: first = (5+6+1)%64=12, then +slot+1 per step (slot 0).
        assert_eq!(rs[0].tokens, vec![12, 13, 14, 15]);
    }

    #[test]
    fn batch_processes_more_requests_than_slots() {
        let mut e = engine(2);
        for id in 0..7 {
            e.submit(Request::greedy(id, vec![id as u32], 3)).unwrap();
        }
        let rs = e.run_to_completion(1000).unwrap();
        assert_eq!(rs.len(), 7);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        for r in &rs {
            assert_eq!(r.tokens.len(), 3);
        }
        // Continuous batching must refill: with 2 slots and 7 requests,
        // decode steps < 7 * 2 (serial would be ~14).
        assert!(e.stats().decode_steps < 14, "steps {}", e.stats().decode_steps);
        assert!(e.stats().mean_occupancy() > 1.0);
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = engine(1);
        // Mock: first token = (2+1)%64 = 3; then 4, 5, ...
        let mut r = Request::greedy(9, vec![2], 100);
        r.stop_token = Some(5);
        e.submit(r).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs[0].finish_reason, FinishReason::Stop);
        assert_eq!(rs[0].tokens, vec![3, 4, 5]);
    }

    #[test]
    fn capacity_bound_respected() {
        let mut e = engine(1); // max_seq 32, prefill_len 16
        let prompt: Vec<u32> = (0..16).collect();
        e.submit(Request::greedy(3, prompt, 10_000)).unwrap();
        let rs = e.run_to_completion(10_000).unwrap();
        assert_eq!(rs[0].finish_reason, FinishReason::Capacity);
        // pos starts at 16, finishes when pos+1 >= 32 → 15 generated+1 first.
        assert!(rs[0].tokens.len() <= 16);
        assert!(!rs[0].tokens.is_empty());
    }

    #[test]
    fn one_token_requests_never_enter_decode() {
        let mut e = engine(2);
        e.submit(Request::greedy(1, vec![1], 1)).unwrap();
        e.submit(Request::greedy(2, vec![2], 1)).unwrap();
        let rs = e.run_to_completion(10).unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(e.stats().decode_steps, 0);
        assert!(rs.iter().all(|r| r.tokens.len() == 1));
    }

    #[test]
    fn timing_fields_populated() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 3)).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        let t = &rs[0].timing;
        assert!(t.first_token >= t.prefill);
        assert!(t.decode > std::time::Duration::ZERO);
    }

    #[test]
    fn queue_backpressure_propagates() {
        let mut e = Engine::new(
            MockBackend::new(1, 32, 64),
            EngineConfig {
                queue_capacity: 2,
                ..EngineConfig::default()
            },
        );
        e.submit(Request::greedy(1, vec![1], 2)).unwrap();
        e.submit(Request::greedy(2, vec![1], 2)).unwrap();
        assert!(e.submit(Request::greedy(3, vec![1], 2)).is_err());
    }

    #[test]
    fn cancel_removes_a_queued_request() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 4)).unwrap();
        e.submit(Request::greedy(2, vec![2], 4)).unwrap();
        assert!(e.cancel(2), "queued request must be cancellable");
        assert_eq!(e.stats().cancelled, 1);
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 1, "only the surviving request completes");
    }

    #[test]
    fn cancel_frees_an_active_slot_for_the_next_admission() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 100)).unwrap();
        e.step().unwrap(); // admit into the only slot, start generating
        assert_eq!(e.active(), 1);
        assert!(e.cancel(1), "active request must be cancellable");
        assert_eq!(e.active(), 0, "cancel must free the batch slot");
        assert_eq!(e.stats().cancelled, 1);
        // The freed slot admits and completes the next request.
        e.submit(Request::greedy(2, vec![2], 3)).unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 2);
        assert_eq!(rs[0].tokens.len(), 3);
    }

    #[test]
    fn cancel_unknown_id_is_a_no_op() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 2)).unwrap();
        assert!(!e.cancel(99));
        assert_eq!(e.stats().cancelled, 0);
        assert_eq!(e.run_to_completion(100).unwrap().len(), 1);
    }

    #[test]
    fn stats_account_tokens() {
        let mut e = engine(2);
        for id in 0..4 {
            e.submit(Request::greedy(id, vec![1], 5)).unwrap();
        }
        let rs = e.run_to_completion(1000).unwrap();
        let total: usize = rs.iter().map(|r| r.tokens.len()).sum();
        assert_eq!(e.stats().tokens as usize, total);
        assert_eq!(e.stats().completed, 4);
    }

    #[test]
    fn high_priority_preempts_and_victim_resumes_bit_identically() {
        // Baseline: the victim generating alone, never preempted.
        let mut base = engine(1);
        base.submit(Request::greedy(1, vec![5, 6], 8)).unwrap();
        let baseline = base.run_to_completion(100).unwrap();

        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![5, 6], 8).with_priority(-2))
            .unwrap();
        e.step().unwrap();
        e.step().unwrap();
        // Interactive request arrives mid-generation; the only slot is
        // held by a strictly lower class → preempt.
        e.submit(Request::greedy(2, vec![1], 2).with_priority(3))
            .unwrap();
        let rs = e.run_to_completion(200).unwrap();
        assert_eq!(e.stats().preemptions, 1);
        let victim = rs.iter().find(|r| r.id == 1).unwrap();
        let vip = rs.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(vip.tokens.len(), 2);
        assert_eq!(
            victim.tokens, baseline[0].tokens,
            "preempt + KV-splice resume must be lossless"
        );
        assert_eq!(victim.finish_reason, FinishReason::Length);
    }

    #[test]
    fn victim_resuming_in_a_different_slot_stays_bit_identical() {
        let be = || DigestBackend::with_digest(0x5EED, 2, 64, 256);
        let baseline_for = |id: u64, prompt: Vec<u32>, n: usize| {
            let mut b = Engine::new(be(), EngineConfig::default());
            b.submit(Request::greedy(id, prompt, n)).unwrap();
            b.run_to_completion(1000).unwrap().remove(0).tokens
        };
        let base1 = baseline_for(1, vec![9, 9], 20);
        let base2 = baseline_for(2, vec![8], 30);

        let mut e = Engine::new(be(), EngineConfig::default());
        e.submit(Request::greedy(1, vec![9, 9], 20).with_priority(-1))
            .unwrap();
        e.submit(Request::greedy(2, vec![8], 30).with_priority(-1))
            .unwrap();
        e.step().unwrap(); // both admitted, one decode step
        // Two interactive arrivals evict BOTH low-class generations;
        // the shorter one finishes first, so victims resume in slots
        // they did not originally occupy.
        e.submit(Request::greedy(3, vec![7], 2).with_priority(4))
            .unwrap();
        e.submit(Request::greedy(4, vec![6], 6).with_priority(4))
            .unwrap();
        let rs = e.run_to_completion(1000).unwrap();
        assert_eq!(e.stats().preemptions, 2);
        assert_eq!(rs.len(), 4);
        assert_eq!(
            rs.iter().find(|r| r.id == 1).unwrap().tokens,
            base1,
            "slot reassignment must not leak into tokens"
        );
        assert_eq!(rs.iter().find(|r| r.id == 2).unwrap().tokens, base2);
    }

    #[test]
    fn preemption_off_never_interrupts_active_work() {
        let mut e = Engine::new(
            MockBackend::new(1, 32, 64),
            EngineConfig {
                preemption: false,
                ..EngineConfig::default()
            },
        );
        e.submit(Request::greedy(1, vec![1], 10).with_priority(-5))
            .unwrap();
        e.step().unwrap();
        e.submit(Request::greedy(2, vec![2], 2).with_priority(5))
            .unwrap();
        let rs = e.run_to_completion(100).unwrap();
        assert_eq!(e.stats().preemptions, 0);
        let order: Vec<u64> = rs.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 2], "batch work ran to completion first");
    }

    #[test]
    fn equal_class_never_preempts() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 10)).unwrap();
        e.step().unwrap();
        e.submit(Request::greedy(2, vec![2], 2)).unwrap();
        e.run_to_completion(100).unwrap();
        assert_eq!(e.stats().preemptions, 0, "strict < comparison, no thrash");
    }

    #[test]
    fn queued_past_deadline_requests_expire_instead_of_running() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 50)).unwrap();
        e.step().unwrap(); // occupies the only slot
        e.submit(Request::greedy(2, vec![2], 5).with_deadline(Duration::ZERO))
            .unwrap();
        let rs = e.step().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 2);
        assert_eq!(rs[0].finish_reason, FinishReason::Expired);
        assert!(rs[0].tokens.is_empty());
        assert_eq!(e.stats().expired, 1);
        assert_eq!(e.stats().completed, 0, "expiry is not a completion");
        // The blocker still finishes normally.
        let rest = e.run_to_completion(100).unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, 1);
    }

    #[test]
    fn expired_preempted_request_reports_its_generated_prefix() {
        let mut e = engine(1);
        let mut r = Request::greedy(7, vec![1], 50).with_deadline(Duration::ZERO);
        r.resume = Some(Box::new(ResumeState {
            generated: vec![3, 4],
            pos: 5,
            last: 4,
            kv: None,
            timing: Timing::default(),
        }));
        e.submit(r).unwrap();
        let rs = e.step().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].finish_reason, FinishReason::Expired);
        assert_eq!(rs[0].tokens, vec![3, 4], "partial prefix survives expiry");
    }

    /// Satellite regression: `deadline_ms` bounds **total** time, not
    /// just queue wait — an in-flight generation whose deadline passes
    /// is stopped at the next engine step and answered with the prefix
    /// it had produced.
    #[test]
    fn running_past_deadline_generations_stop_with_their_prefix() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![5, 6], 50).with_deadline(Duration::from_millis(20)))
            .unwrap();
        e.step().unwrap(); // admits + first token, well inside the deadline
        e.step().unwrap(); // second token
        std::thread::sleep(Duration::from_millis(60));
        let rs = e.step().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].id, 1);
        assert_eq!(rs[0].finish_reason, FinishReason::Expired);
        // Mock chain from prompt [5,6]: 12, 13, ... — the prefix the
        // two in-deadline steps produced rides on the expired reply.
        assert_eq!(rs[0].tokens, vec![12, 13], "prefix survives running expiry");
        assert_eq!(e.stats().expired, 1);
        assert_eq!(e.stats().completed, 0, "expiry is not a completion");
        assert!(!e.has_work(), "the slot was actually freed");
    }

    /// The tentpole property: for seeded prompts, every proposal depth
    /// `k ∈ {1,2,4,8}`, preemption on and off, and both a perfectly
    /// aligned draft (same digest → 100% acceptance) and an unrelated
    /// one (~zero acceptance), speculative decode emits streams
    /// bit-identical to plain target-only greedy decode — finish
    /// reasons (length, stop token, KV capacity) included.
    #[test]
    fn speculative_decode_is_bit_identical_to_plain_greedy() {
        const TARGET: u64 = 0xAB5EED;
        let target = || DigestBackend::with_digest(TARGET, 2, 64, 256);

        // Seeded request mix: varied budgets, one capacity-bound run,
        // one stop-token truncation (probed from the greedy chain so it
        // actually fires mid-stream).
        let probe = {
            let mut e = Engine::new(target(), EngineConfig::default());
            e.submit(Request::greedy(2, vec![20, 21], 8)).unwrap();
            e.run_to_completion(100).unwrap()[0].tokens[2]
        };
        let requests = || -> Vec<Request> {
            let mut rs = vec![
                Request::greedy(0, vec![1, 2, 3], 7),
                Request::greedy(1, vec![9], 12),
                Request::greedy(2, vec![20, 21], 8),
                Request::greedy(3, vec![4, 4, 4], 100), // KV-capacity bound
                Request::greedy(4, vec![7, 8], 5),
            ];
            rs[2].stop_token = Some(probe);
            rs
        };

        let mut baseline: Vec<(u64, Vec<u32>, FinishReason)> = {
            let mut e = Engine::new(target(), EngineConfig::default());
            for r in requests() {
                e.submit(r).unwrap();
            }
            e.run_to_completion(10_000)
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.tokens, r.finish_reason))
                .collect()
        };
        baseline.sort_by_key(|x| x.0);
        assert!(
            baseline.iter().any(|(_, _, f)| *f == FinishReason::Stop),
            "probe stop token never fired — weak test"
        );
        assert!(
            baseline.iter().any(|(_, _, f)| *f == FinishReason::Capacity),
            "no capacity-bound request — weak test"
        );

        for draft_digest in [TARGET, 0xD00D] {
            for k in [1usize, 2, 4, 8] {
                for preemption in [false, true] {
                    let mut e = Engine::new(
                        target(),
                        EngineConfig {
                            preemption,
                            ..EngineConfig::default()
                        },
                    );
                    let mut draft = DigestBackend::with_digest(draft_digest, 2, 64, 256);
                    let mut st = SpecStats::default();
                    // Stagger submissions so a high-class arrival meets
                    // a running low-class batch (preemption fires when
                    // enabled); priorities must not change the tokens.
                    let mut reqs = requests().into_iter();
                    let mut out = Vec::new();
                    for r in reqs.by_ref().take(2) {
                        e.submit(r.with_priority(-2)).unwrap();
                    }
                    out.extend(e.step_speculative(&mut draft, k, &mut st).unwrap());
                    for r in reqs {
                        e.submit(r.with_priority(3)).unwrap();
                    }
                    let mut steps = 0;
                    while e.has_work() && steps < 10_000 {
                        out.extend(e.step_speculative(&mut draft, k, &mut st).unwrap());
                        steps += 1;
                    }
                    let mut got: Vec<(u64, Vec<u32>, FinishReason)> = out
                        .into_iter()
                        .map(|r| (r.id, r.tokens, r.finish_reason))
                        .collect();
                    got.sort_by_key(|x| x.0);
                    assert_eq!(
                        got, baseline,
                        "stream diverged: draft {draft_digest:#x}, k={k}, \
                         preemption={preemption}"
                    );
                    assert_eq!(st.fallback_steps, 0, "all-greedy load fell back");
                    assert!(st.steps > 0 && st.emitted > 0, "{st:?}");
                    if draft_digest == TARGET {
                        // A perfectly aligned draft is always accepted.
                        assert_eq!(st.accepted, st.proposed, "{st:?}");
                        assert!(
                            st.emitted_per_step() > 1.0,
                            "aligned draft never amortized a step: {st:?}"
                        );
                    }
                    if preemption {
                        assert!(
                            e.stats().preemptions > 0,
                            "staggered classes never preempted — weak test"
                        );
                    }
                }
            }
        }
    }

    /// Sampled requests force plain decode: speculation is greedy-only,
    /// and the fallback must leave the RNG-driven stream exactly as a
    /// plain engine produces it.
    #[test]
    fn sampled_requests_fall_back_to_plain_decode() {
        let run = |speculative: bool| -> (Vec<u32>, u64) {
            let mut e = Engine::new(
                DigestBackend::with_digest(0xCAFE, 2, 64, 256),
                EngineConfig::default(),
            );
            let mut r = Request::greedy(1, vec![3, 1], 6);
            r.temperature = 0.8;
            r.top_k = 16;
            e.submit(r).unwrap();
            let mut st = SpecStats::default();
            let mut out = Vec::new();
            let mut steps = 0;
            while e.has_work() && steps < 1_000 {
                if speculative {
                    let mut draft = DigestBackend::with_digest(0xBEEF, 2, 64, 256);
                    out.extend(e.step_speculative(&mut draft, 4, &mut st).unwrap());
                } else {
                    out.extend(e.step().unwrap());
                }
                steps += 1;
            }
            (out.pop().unwrap().tokens, st.fallback_steps)
        };
        let (plain, _) = run(false);
        let (spec, fallbacks) = run(true);
        assert_eq!(spec, plain, "fallback changed a sampled stream");
        assert!(fallbacks > 0, "sampled request never tripped the fallback");
    }

    #[test]
    fn cancel_after_same_step_retirement_is_a_clean_no_op() {
        // Single-threaded analogue of "cancel lands after pop, before
        // batch insert": a 1-token request is popped and retired inside
        // one step, so a dead-waiter cancel arriving right after finds
        // it neither queued nor active. The cancel must report false
        // and leave every gauge reconciled.
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![1], 1)).unwrap();
        let rs = e.step().unwrap();
        assert_eq!(rs.len(), 1);
        assert!(!e.cancel(1));
        assert_eq!(e.stats().cancelled, 0);
        let qs = e.queue_stats();
        assert_eq!(qs.depth, 0);
        assert_eq!(qs.admitted, qs.dispatched, "no request leaked in the gap");
    }

    #[test]
    fn cancel_reaches_a_preempted_requeued_request() {
        let mut e = engine(1);
        e.submit(Request::greedy(1, vec![5, 6], 30).with_priority(-1))
            .unwrap();
        e.step().unwrap();
        e.submit(Request::greedy(2, vec![1], 10).with_priority(3))
            .unwrap();
        e.step().unwrap(); // preempts id 1; id 2 now holds the slot
        assert_eq!(e.stats().preemptions, 1);
        assert!(e.cancel(1), "checkpointed victim must be cancellable while re-queued");
        assert_eq!(e.stats().cancelled, 1);
        let rs = e.run_to_completion(100).unwrap();
        assert!(rs.iter().all(|r| r.id == 2), "victim never resurfaces");
    }
}
