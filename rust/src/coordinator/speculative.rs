//! Speculative decoding across two co-resident models.
//!
//! A small **draft** model proposes `k` greedy tokens per speculating
//! slot; the **target** model verifies the whole proposal block in one
//! batched evaluation ([`crate::coordinator::Backend::argmax_rows`])
//! and accepts the longest prefix that matches its own argmax chain.
//! Acceptance is **bit-exact greedy-equivalent**: the emitted stream is
//! identical, token for token, to what target-only greedy decode would
//! have produced — speculation changes only how many target weight
//! passes each token costs, never the tokens.
//!
//! ## The acceptance rule
//!
//! With target state (last token `L`, next write position `P`) and
//! draft proposals `d₁ … d_k` (the draft's own greedy chain seeded from
//! `(L, P)`), the target evaluates `k + 1` rows in one batched call:
//!
//! ```text
//! row 0: (L,   P)      → v₀        (the target's own next token)
//! row i: (dᵢ,  P + i)  → vᵢ        for i = 1 … k
//! ```
//!
//! Emission walks the verdicts: emit `v₀`; if `d₁ = v₀` the row-1 input
//! was the true next token, so `v₁` is the true token after it — emit it
//! and continue; the first mismatch `dᵢ ≠ vᵢ₋₁` stops the walk *after*
//! emitting the correction `vᵢ₋₁`. If all `k` proposals match, the
//! bonus verdict `v_k` is emitted too. By induction every emitted token
//! equals the target-only greedy token at its position, and each
//! speculative step emits between 1 and `k + 1` tokens per slot.
//!
//! [`accept_longest_prefix`] implements exactly that walk;
//! [`crate::coordinator::Engine::step_speculative`] wires it into the
//! continuous batcher (per-token finish checks included, so stop
//! tokens, length budgets, and KV capacity truncate the emission at
//! precisely the token target-only decode would have stopped at).

use crate::{Error, Result};

/// Upper bound on the per-step proposal depth `k` (a draft chain this
/// long would be all misprediction long before the cap matters).
pub const SPEC_K_MAX: usize = 64;

/// Parsed `--speculate draft=NAME,target=NAME,k=K` configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecConfig {
    /// Routing name of the proposing (draft) model.
    pub draft: String,
    /// Routing name of the verifying (target) model.
    pub target: String,
    /// Proposal depth: draft tokens proposed per speculative step.
    pub k: usize,
}

impl SpecConfig {
    /// Parse the CLI flag value: comma-separated `draft=NAME`,
    /// `target=NAME`, `k=K` (each exactly once, any order).
    pub fn parse(value: &str) -> Result<Self> {
        let (mut draft, mut target, mut k) = (None, None, None);
        for part in value.split(',') {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                Error::InvalidArg(format!(
                    "--speculate expects draft=NAME,target=NAME,k=K, got {part:?}"
                ))
            })?;
            let slot = match key {
                "draft" => &mut draft,
                "target" => &mut target,
                "k" => &mut k,
                other => {
                    return Err(Error::InvalidArg(format!(
                        "--speculate: unknown key {other:?} (expected draft, target, k)"
                    )))
                }
            };
            if slot.replace(val.to_string()).is_some() {
                return Err(Error::InvalidArg(format!(
                    "--speculate: duplicate key {key:?}"
                )));
            }
        }
        let draft = draft
            .ok_or_else(|| Error::InvalidArg("--speculate: missing draft=NAME".into()))?;
        let target = target
            .ok_or_else(|| Error::InvalidArg("--speculate: missing target=NAME".into()))?;
        let k_str =
            k.ok_or_else(|| Error::InvalidArg("--speculate: missing k=K".into()))?;
        let k: usize = k_str.parse().map_err(|_| {
            Error::InvalidArg(format!("--speculate: k must be a positive integer, got {k_str:?}"))
        })?;
        if k == 0 || k > SPEC_K_MAX {
            return Err(Error::InvalidArg(format!(
                "--speculate: k must be in 1..={SPEC_K_MAX}, got {k}"
            )));
        }
        if draft == target {
            return Err(Error::InvalidArg(
                "--speculate: draft and target must be different models".into(),
            ));
        }
        Ok(SpecConfig { draft, target, k })
    }
}

/// Counters for the speculative arm, surfaced as the `spec_*` family of
/// the server's `{"stats":true}` line.
#[derive(Debug, Clone, Default)]
pub struct SpecStats {
    /// Speculative verify steps executed (each: one draft proposal
    /// chain + one batched target verification).
    pub steps: u64,
    /// Draft tokens proposed.
    pub proposed: u64,
    /// Proposed tokens the target's argmax confirmed.
    pub accepted: u64,
    /// Tokens actually emitted by speculative steps (accepted prefixes
    /// plus the per-slot correction/bonus token, truncated at finish
    /// conditions exactly like target-only decode).
    pub emitted: u64,
    /// Steps that fell back to plain decode (a sampled request in the
    /// batch, or a KV-bound backend declining stateless verification).
    pub fallback_steps: u64,
}

impl SpecStats {
    /// Fraction of proposed draft tokens the target accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Mean tokens emitted per speculative step and slot — the
    /// headline speedup knob (target weight passes per token is its
    /// reciprocal).
    pub fn emitted_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.emitted as f64 / self.steps as f64
        }
    }
}

/// The acceptance walk from the module docs: given the draft's
/// `proposals` (`d₁ … d_k`) and the target's `verdicts` (`v₀ … v_k`,
/// one more than proposals), return the emitted tokens — the longest
/// verified prefix plus the correction (on first mismatch) or the
/// bonus verdict (all matched). Always emits at least one token.
pub fn accept_longest_prefix(proposals: &[u32], verdicts: &[u32]) -> Vec<u32> {
    debug_assert_eq!(verdicts.len(), proposals.len() + 1);
    let mut out = Vec::with_capacity(verdicts.len());
    for (i, &v) in verdicts.iter().enumerate() {
        out.push(v);
        if proposals.get(i).copied() != Some(v) {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_any_key_order() {
        let c = SpecConfig::parse("draft=small,target=big,k=4").unwrap();
        assert_eq!(
            c,
            SpecConfig {
                draft: "small".into(),
                target: "big".into(),
                k: 4
            }
        );
        assert_eq!(SpecConfig::parse("k=1,draft=a,target=b").unwrap().k, 1);
    }

    #[test]
    fn parse_rejects_malformed_values() {
        for bad in [
            "",
            "draft=a",
            "draft=a,target=b",
            "draft=a,target=b,k=0",
            "draft=a,target=b,k=-1",
            "draft=a,target=b,k=nope",
            "draft=a,target=b,k=65",
            "draft=a,target=a,k=2",
            "draft=a,draft=b,target=c,k=2",
            "draft=a,target=b,k=2,zz=1",
            "draftb,k=2",
        ] {
            assert!(SpecConfig::parse(bad).is_err(), "must reject {bad:?}");
        }
    }

    #[test]
    fn acceptance_walk_matches_the_rule() {
        // All proposals match: accepted prefix + bonus verdict.
        assert_eq!(
            accept_longest_prefix(&[5, 6, 7], &[5, 6, 7, 8]),
            vec![5, 6, 7, 8]
        );
        // First proposal wrong: single corrected token.
        assert_eq!(accept_longest_prefix(&[9, 6, 7], &[5, 6, 7, 8]), vec![5]);
        // Mismatch mid-chain: matched prefix + the correction.
        assert_eq!(
            accept_longest_prefix(&[5, 9, 7], &[5, 6, 7, 8]),
            vec![5, 6]
        );
        // k = 0 (no proposals): plain greedy, one token.
        assert_eq!(accept_longest_prefix(&[], &[3]), vec![3]);
    }
}
