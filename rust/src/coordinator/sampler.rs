//! Token sampling: greedy argmax, temperature scaling, top-k truncation.

use crate::rng::Rng;

/// Per-request sampling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SampleCfg {
    /// 0 ⇒ greedy argmax; otherwise softmax temperature.
    pub temperature: f32,
    /// 0 ⇒ no truncation; otherwise keep the k most likely tokens.
    pub top_k: usize,
}

/// Seeded sampler (deterministic per engine run).
#[derive(Debug, Clone)]
pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    /// Sampler with a fixed seed.
    pub fn new(seed: u64) -> Self {
        Sampler { rng: Rng::new(seed) }
    }

    /// Sample one token id from a logits row.
    pub fn sample(&mut self, logits: &[f32], cfg: SampleCfg) -> u32 {
        debug_assert!(!logits.is_empty());
        if cfg.temperature <= 0.0 {
            return argmax(logits) as u32;
        }
        // Collect candidate (index, logit) pairs, top-k truncated.
        let mut cand: Vec<(usize, f32)> = logits.iter().copied().enumerate().collect();
        if cfg.top_k > 0 && cfg.top_k < cand.len() {
            cand.sort_by(|a, b| b.1.total_cmp(&a.1));
            cand.truncate(cfg.top_k);
        }
        // Stable softmax at the given temperature.
        let max = cand.iter().map(|&(_, l)| l).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f32> = cand
            .iter()
            .map(|&(_, l)| ((l - max) / cfg.temperature).exp())
            .collect();
        let pick = self.rng.categorical(&weights);
        cand[pick].0 as u32
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(1);
        let logits = [0.1, 2.0, -1.0, 1.9];
        let cfg = SampleCfg {
            temperature: 0.0,
            top_k: 0,
        };
        for _ in 0..10 {
            assert_eq!(s.sample(&logits, cfg), 1);
        }
    }

    #[test]
    fn low_temperature_concentrates_on_mode() {
        let mut s = Sampler::new(2);
        let logits = [0.0, 5.0, 0.0, 0.0];
        let cfg = SampleCfg {
            temperature: 0.3,
            top_k: 0,
        };
        let hits = (0..200).filter(|_| s.sample(&logits, cfg) == 1).count();
        assert!(hits > 190, "mode hit {hits}/200");
    }

    #[test]
    fn top_k_excludes_tail() {
        let mut s = Sampler::new(3);
        let logits = [3.0, 2.9, -10.0, -10.0];
        let cfg = SampleCfg {
            temperature: 1.0,
            top_k: 2,
        };
        for _ in 0..100 {
            let t = s.sample(&logits, cfg);
            assert!(t == 0 || t == 1, "sampled tail token {t}");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut s = Sampler::new(4);
        let logits = [1.0, 0.9, 0.8, 0.7];
        let cfg = SampleCfg {
            temperature: 10.0,
            top_k: 0,
        };
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[s.sample(&logits, cfg) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b), "all tokens reachable");
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 1.0, 0.5]), 0);
    }
}
