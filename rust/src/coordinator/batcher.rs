//! Bounded, priority-aware admission queue with aging, deadlines, and
//! occupancy statistics.
//!
//! The continuous batcher itself lives in [`super::engine`]; this module
//! owns admission policy. Requests are ordered by *effective* priority
//! (highest first), FIFO within a class. Effective priority rises with
//! wait time ("aging") so low-priority work can never starve: a request
//! that has waited `k` aging intervals sorts as `priority + k`, capped
//! at [`PRIORITY_MAX`] — once everything old reaches the cap, order
//! degenerates to pure FIFO. Aging affects *dequeue order only*; the
//! engine's preemption decisions always compare static classes, so aged
//! batch work can be scheduled fairly without ever preempting anyone.
//!
//! This queue owns the *queued* half of deadline enforcement:
//! [`AdmissionQueue::expire`] sweeps out requests whose deadline passed
//! while they waited, so dead work is answered (with a distinguishable
//! expired error upstream) instead of occupying a batch slot. The
//! engine enforces the *running* half, stopping an admitted generation
//! whose deadline passes mid-flight. An id → key index keeps [`remove`] and
//! [`expire`] bookkeeping O(log n) per affected entry — dead-waiter
//! sweeps on deep queues no longer pay a linear scan per cancel.
//!
//! [`remove`]: AdmissionQueue::remove
//! [`PRIORITY_MAX`]: super::request::PRIORITY_MAX

use super::request::{Request, PRIORITY_MAX};
use crate::{Error, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, HashMap};
use std::time::{Duration, Instant};

/// BTreeMap ordering key: effective priority descending, then a
/// sequence number ascending (FIFO within a class; preemption requeues
/// use sequence numbers *below* every normal push so an interrupted
/// generation resumes at the front of its class).
type Key = (Reverse<i64>, u64);

/// Sequence numbers above this are normal pushes (ascending), below it
/// are preemption requeues (descending).
const SEQ_ORIGIN: u64 = 1 << 32;

/// Queue statistics snapshot.
///
/// Conservation invariant (asserted by property tests): every request
/// that ever entered the queue left it exactly one way, so
/// `admitted + requeued == depth + dispatched + removed + expired`
/// holds after every operation — a gauge that drifts negative or leaks
/// after cancels breaks this identity immediately.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    /// Requests currently waiting.
    pub depth: usize,
    /// Total admitted since construction.
    pub admitted: u64,
    /// Total rejected (queue full).
    pub rejected: u64,
    /// Total handed to the engine.
    pub dispatched: u64,
    /// Total removed by id (dead-waiter cancels).
    pub removed: u64,
    /// Total swept out by deadline expiry.
    pub expired: u64,
    /// Preempted generations re-queued at the front of their class.
    pub requeued: u64,
    /// Entries whose effective priority was bumped by aging.
    pub aging_promotions: u64,
    /// Waiting requests per *static* class, highest class first.
    pub by_class: Vec<(i32, usize)>,
}

/// Bounded priority admission queue (see module docs).
#[derive(Debug)]
pub struct AdmissionQueue {
    q: BTreeMap<Key, Request>,
    /// id → ordering key. Ids are unique queue-wide (the server remaps
    /// wire ids upward to guarantee it).
    index: HashMap<u64, Key>,
    capacity: usize,
    aging: Option<Duration>,
    next_seq: u64,
    next_front_seq: u64,
    stats: QueueStats,
}

impl AdmissionQueue {
    /// Queue holding at most `capacity` waiting requests, no aging.
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            q: BTreeMap::new(),
            index: HashMap::new(),
            capacity: capacity.max(1),
            aging: None,
            next_seq: SEQ_ORIGIN + 1,
            next_front_seq: SEQ_ORIGIN,
            stats: QueueStats::default(),
        }
    }

    /// Set (or disable) the aging interval: every elapsed interval a
    /// waiting request's effective priority rises one class.
    pub fn set_aging(&mut self, aging: Option<Duration>) {
        self.aging = aging.filter(|d| !d.is_zero());
    }

    /// Effective priority of `r` after waiting until `now`.
    fn effective(&self, r: &Request, now: Instant) -> i64 {
        let base = r.priority as i64;
        let Some(interval) = self.aging else {
            return base;
        };
        let waited = r
            .enqueued_at
            .map(|t| now.saturating_duration_since(t))
            .unwrap_or(Duration::ZERO);
        let steps = (waited.as_nanos() / interval.as_nanos().max(1)).min(64) as i64;
        (base + steps).min(PRIORITY_MAX as i64)
    }

    /// Re-key every entry whose aged effective priority rose. O(n) when
    /// it runs; callers (pop/expire) invoke it at dispatch points so a
    /// deep idle queue pays nothing.
    fn age(&mut self, now: Instant) {
        if self.aging.is_none() {
            return;
        }
        let promote: Vec<(Key, i64)> = self
            .q
            .iter()
            .filter_map(|(&key, r)| {
                let eff = self.effective(r, now);
                (eff > key.0 .0).then_some((key, eff))
            })
            .collect();
        for (key, eff) in promote {
            if let Some(r) = self.q.remove(&key) {
                let new_key = (Reverse(eff), key.1);
                self.index.insert(r.id, new_key);
                self.q.insert(new_key, r);
                self.stats.aging_promotions += 1;
            }
        }
    }

    /// Enqueue a request; errors when the queue is full (backpressure —
    /// callers see the rejection rather than unbounded latency).
    pub fn push(&mut self, mut r: Request) -> Result<()> {
        if self.q.len() >= self.capacity {
            self.stats.rejected += 1;
            return Err(Error::Engine(format!(
                "queue full (capacity {})",
                self.capacity
            )));
        }
        r.enqueued_at.get_or_insert_with(Instant::now);
        let key = (Reverse(r.priority as i64), self.next_seq);
        self.next_seq += 1;
        self.index.insert(r.id, key);
        self.q.insert(key, r);
        self.stats.admitted += 1;
        Ok(())
    }

    /// Re-queue a preempted generation at the *front* of its static
    /// class, bypassing the capacity check: the request already passed
    /// admission once and its slot just freed, so net queue+batch
    /// population is unchanged. `enqueued_at` is left as the caller set
    /// it (the engine restarts it at preemption time so queue-wait
    /// accounting does not double-count the first wait).
    pub fn push_front(&mut self, r: Request) {
        let key = (Reverse(r.priority as i64), self.next_front_seq);
        self.next_front_seq -= 1;
        self.index.insert(r.id, key);
        self.q.insert(key, r);
        self.stats.requeued += 1;
    }

    /// Pop the highest-effective-priority waiting request (FIFO within
    /// a class). Runs an aging sweep first so promotions take effect at
    /// exactly the dispatch points.
    pub fn pop(&mut self) -> Option<Request> {
        self.age(Instant::now());
        let (key, r) = self.q.pop_first()?;
        debug_assert_eq!(self.index.get(&r.id), Some(&key));
        self.index.remove(&r.id);
        self.stats.dispatched += 1;
        Some(r)
    }

    /// The next request [`pop`] would return, ignoring any aging
    /// promotions that have not been applied yet. The engine's
    /// preemption check reads the head's *static* class from here.
    ///
    /// [`pop`]: AdmissionQueue::pop
    pub fn peek(&self) -> Option<&Request> {
        self.q.first_key_value().map(|(_, r)| r)
    }

    /// Remove a queued request by id (dead-waiter cancellation),
    /// O(log n) through the id index. Counted under `removed` so the
    /// depth gauge stays reconcilable with the admission counters.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let key = self.index.remove(&id)?;
        let r = self.q.remove(&key);
        debug_assert!(r.is_some(), "index said {id} was queued");
        if r.is_some() {
            self.stats.removed += 1;
        }
        r
    }

    /// Sweep out every queued request whose deadline has passed at
    /// `now`, returning them (resume state intact) so the caller can
    /// answer each with a distinguishable expired error. This sweep
    /// covers the *queued* side only; the engine separately stops
    /// running generations whose deadline passes mid-flight.
    pub fn expire(&mut self, now: Instant) -> Vec<Request> {
        self.age(now);
        let dead: Vec<Key> = self
            .q
            .iter()
            .filter_map(|(&key, r)| {
                let deadline = r.deadline?;
                let enq = r.enqueued_at?;
                (now.saturating_duration_since(enq) > deadline).then_some(key)
            })
            .collect();
        let mut out = Vec::with_capacity(dead.len());
        for key in dead {
            if let Some(r) = self.q.remove(&key) {
                self.index.remove(&r.id);
                self.stats.expired += 1;
                out.push(r);
            }
        }
        out
    }

    /// Number waiting.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when nothing waits.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Statistics snapshot (depth and per-class histogram computed from
    /// the live queue).
    pub fn stats(&self) -> QueueStats {
        let mut by_class: BTreeMap<Reverse<i32>, usize> = BTreeMap::new();
        for r in self.q.values() {
            *by_class.entry(Reverse(r.priority)).or_insert(0) += 1;
        }
        QueueStats {
            depth: self.q.len(),
            by_class: by_class.into_iter().map(|(Reverse(p), n)| (p, n)).collect(),
            ..self.stats.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::greedy(id, vec![1], 4)
    }

    /// The conservation identity from the [`QueueStats`] docs.
    fn assert_conserved(q: &AdmissionQueue) {
        let s = q.stats();
        assert_eq!(
            s.admitted + s.requeued,
            s.depth as u64 + s.dispatched + s.removed + s.expired,
            "queue accounting must conserve requests: {s:?}"
        );
        assert_eq!(s.depth, q.len());
        assert_eq!(s.by_class.iter().map(|&(_, n)| n).sum::<usize>(), s.depth);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = AdmissionQueue::new(8);
        for id in 0..5 {
            q.push(req(id)).unwrap();
        }
        for id in 0..5 {
            assert_eq!(q.pop().unwrap().id, id);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn higher_priority_jumps_the_line_fifo_within_class() {
        let mut q = AdmissionQueue::new(8);
        q.push(req(0)).unwrap();
        q.push(req(1).with_priority(2)).unwrap();
        q.push(req(2).with_priority(-3)).unwrap();
        q.push(req(3).with_priority(2)).unwrap();
        q.push(req(4)).unwrap();
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![1, 3, 0, 4, 2]);
    }

    #[test]
    fn rejects_when_full_and_counts() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(0)).unwrap();
        q.push(req(1)).unwrap();
        assert!(q.push(req(2)).is_err());
        let s = q.stats();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.depth, 2);
        assert_conserved(&q);
    }

    #[test]
    fn enqueue_timestamps_set() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(0)).unwrap();
        assert!(q.pop().unwrap().enqueued_at.is_some());
    }

    #[test]
    fn remove_takes_out_the_matching_id_only() {
        let mut q = AdmissionQueue::new(8);
        for id in 0..4 {
            q.push(req(id)).unwrap();
        }
        assert_eq!(q.remove(2).unwrap().id, 2);
        assert!(q.remove(2).is_none(), "already removed");
        assert!(q.remove(99).is_none(), "never enqueued");
        // FIFO order of the survivors is untouched, and the counters
        // treat the removal as neither a dispatch nor a rejection.
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(rest, vec![0, 1, 3]);
        assert_eq!(q.stats().admitted, 4);
        assert_eq!(q.stats().rejected, 0);
        assert_eq!(q.stats().removed, 1);
        assert_conserved(&q);
    }

    #[test]
    fn dispatch_counter_tracks_pops() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(0)).unwrap();
        q.push(req(1)).unwrap();
        q.pop();
        assert_eq!(q.stats().dispatched, 1);
        assert_eq!(q.stats().depth, 1);
    }

    #[test]
    fn push_front_resumes_before_equal_class_waiters() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(0)).unwrap();
        q.push(req(1)).unwrap();
        // A preempted id 9 of the same class re-queues ahead of both,
        // even at capacity.
        q.push(req(2)).unwrap();
        q.push(req(3)).unwrap();
        assert!(q.push(req(4)).is_err(), "at capacity");
        q.push_front(req(9));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![9, 0, 1, 2, 3]);
        assert_conserved(&q);
    }

    #[test]
    fn push_front_still_yields_to_higher_class() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(0).with_priority(5)).unwrap();
        q.push_front(req(9).with_priority(-2));
        assert_eq!(q.pop().unwrap().id, 0, "class beats requeue position");
        assert_eq!(q.pop().unwrap().id, 9);
    }

    #[test]
    fn aging_promotes_low_priority_instead_of_starving_it() {
        let mut q = AdmissionQueue::new(8);
        q.set_aging(Some(Duration::from_millis(1)));
        let mut old = req(0).with_priority(-8);
        // Backdate far enough that aging lifts it to PRIORITY_MAX.
        old.enqueued_at = Some(Instant::now() - Duration::from_secs(1));
        q.push(old).unwrap();
        q.push(req(1).with_priority(3)).unwrap();
        assert_eq!(
            q.pop().unwrap().id,
            0,
            "aged batch request must outrank a fresh priority-3 one"
        );
        assert!(q.stats().aging_promotions >= 1);
        // The *static* class is untouched by aging — preemption
        // decisions keep seeing -8.
        assert_eq!(q.pop().unwrap().priority, 3);
        assert_conserved(&q);
    }

    #[test]
    fn aging_disabled_means_static_order() {
        let mut q = AdmissionQueue::new(8);
        let mut old = req(0).with_priority(-1);
        // checked_sub: a monotonic clock epoch under an hour old (fresh
        // CI runner) must not panic the test; `None` keeps push's
        // `enqueued_at = now`, which this test is equally correct under.
        old.enqueued_at = Instant::now().checked_sub(Duration::from_secs(3600));
        q.push(old).unwrap();
        q.push(req(1)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.stats().aging_promotions, 0);
    }

    #[test]
    fn expire_sweeps_only_past_deadline_requests() {
        let mut q = AdmissionQueue::new(8);
        let mut dead = req(0).with_deadline(Duration::from_millis(10));
        dead.enqueued_at = Some(Instant::now() - Duration::from_secs(1));
        q.push(dead).unwrap();
        q.push(req(1).with_deadline(Duration::from_secs(3600))).unwrap();
        q.push(req(2)).unwrap(); // no deadline: never expires
        let expired = q.expire(Instant::now());
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 0);
        assert_eq!(q.len(), 2);
        assert_eq!(q.stats().expired, 1);
        assert!(q.remove(0).is_none(), "expired entry left the index too");
        assert_conserved(&q);
    }

    #[test]
    fn by_class_histogram_counts_static_classes() {
        let mut q = AdmissionQueue::new(8);
        q.push(req(0)).unwrap();
        q.push(req(1).with_priority(2)).unwrap();
        q.push(req(2).with_priority(2)).unwrap();
        q.push(req(3).with_priority(-1)).unwrap();
        assert_eq!(q.stats().by_class, vec![(2, 2), (0, 1), (-1, 1)]);
    }

    /// Satellite regression: a 10k-deep queue with interleaved removes
    /// stays correct and reconciled — the id index makes each remove
    /// O(log n) instead of a linear scan, so this test is also the
    /// canary that the index and the tree never drift apart.
    #[test]
    fn deep_queue_removes_stay_consistent() {
        let mut q = AdmissionQueue::new(10_000);
        for id in 0..10_000u64 {
            q.push(req(id).with_priority((id % 5) as i32 - 2)).unwrap();
        }
        for id in (0..10_000u64).step_by(2) {
            assert_eq!(q.remove(id).map(|r| r.id), Some(id));
        }
        assert_eq!(q.len(), 5_000);
        assert_conserved(&q);
        // Survivors drain strictly by (class desc, FIFO) and every
        // removed id is really gone.
        let mut last: Option<(i32, u64)> = None;
        while let Some(r) = q.pop() {
            assert_eq!(r.id % 2, 1);
            if let Some((lp, lid)) = last {
                assert!(r.priority < lp || (r.priority == lp && r.id > lid));
            }
            last = Some((r.priority, r.id));
        }
        assert_conserved(&q);
    }

    /// Satellite property test: drive a pseudo-random mix of
    /// push/pop/remove/shed/expire/requeue operations and assert the
    /// conservation identity after every single step.
    #[test]
    fn random_op_mix_conserves_accounting() {
        let mut q = AdmissionQueue::new(32);
        q.set_aging(Some(Duration::from_millis(250)));
        let mut rng: u64 = 0x1234_5678_9ABC_DEF0;
        let mut next_id: u64 = 0;
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..2_000 {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            match rng >> 60 {
                0..=5 => {
                    let mut r = req(next_id).with_priority(((rng >> 8) % 9) as i32 - 4);
                    if rng & 1 == 1 {
                        r = r.with_deadline(Duration::from_nanos((rng >> 16) % 50));
                        // Some deadlines are already past at push time.
                        r.enqueued_at = Some(Instant::now() - Duration::from_micros(1));
                    }
                    if q.push(r).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                6..=9 => {
                    if let Some(r) = q.pop() {
                        live.retain(|&id| id != r.id);
                        // Occasionally preempt-requeue what we popped.
                        if rng & 2 == 2 {
                            live.push(r.id);
                            q.push_front(r);
                        }
                    }
                }
                10..=12 => {
                    if !live.is_empty() {
                        let id = live[(rng as usize >> 4) % live.len()];
                        if q.remove(id).is_some() {
                            live.retain(|&x| x != id);
                        }
                    }
                    // Removing a bogus id must be a counted no-op.
                    assert!(q.remove(u64::MAX).is_none());
                }
                _ => {
                    for r in q.expire(Instant::now()) {
                        live.retain(|&id| id != r.id);
                    }
                }
            }
            assert_conserved(&q);
            assert_eq!(q.len(), live.len(), "shadow model and queue agree");
        }
    }
}
