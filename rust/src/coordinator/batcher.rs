//! Bounded FIFO admission queue with occupancy statistics.
//!
//! The continuous batcher itself lives in [`super::engine`]; this module
//! owns admission policy: bounded queue, FIFO order, rejection when
//! full, and the queue-depth / wait-time statistics the serving bench
//! reports.

use super::request::Request;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::time::Instant;

/// Queue statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    /// Requests currently waiting.
    pub depth: usize,
    /// Total admitted since construction.
    pub admitted: u64,
    /// Total rejected (queue full).
    pub rejected: u64,
    /// Total handed to the engine.
    pub dispatched: u64,
}

/// Bounded FIFO admission queue.
#[derive(Debug)]
pub struct AdmissionQueue {
    q: VecDeque<Request>,
    capacity: usize,
    stats: QueueStats,
}

impl AdmissionQueue {
    /// Queue holding at most `capacity` waiting requests.
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            q: VecDeque::new(),
            capacity: capacity.max(1),
            stats: QueueStats::default(),
        }
    }

    /// Enqueue a request; errors when the queue is full (backpressure —
    /// callers see the rejection rather than unbounded latency).
    pub fn push(&mut self, mut r: Request) -> Result<()> {
        if self.q.len() >= self.capacity {
            self.stats.rejected += 1;
            return Err(Error::Engine(format!(
                "queue full (capacity {})",
                self.capacity
            )));
        }
        r.enqueued_at.get_or_insert_with(Instant::now);
        self.q.push_back(r);
        self.stats.admitted += 1;
        Ok(())
    }

    /// Pop the oldest waiting request.
    pub fn pop(&mut self) -> Option<Request> {
        let r = self.q.pop_front();
        if r.is_some() {
            self.stats.dispatched += 1;
        }
        r
    }

    /// Remove a queued request by id (dead-waiter cancellation). The
    /// admitted/dispatched counters are left untouched — the request
    /// was admitted but never dispatched.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let pos = self.q.iter().position(|r| r.id == id)?;
        self.q.remove(pos)
    }

    /// Number waiting.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when nothing waits.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            depth: self.q.len(),
            ..self.stats.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::greedy(id, vec![1], 4)
    }

    #[test]
    fn fifo_order_preserved() {
        let mut q = AdmissionQueue::new(8);
        for id in 0..5 {
            q.push(req(id)).unwrap();
        }
        for id in 0..5 {
            assert_eq!(q.pop().unwrap().id, id);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn rejects_when_full_and_counts() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(0)).unwrap();
        q.push(req(1)).unwrap();
        assert!(q.push(req(2)).is_err());
        let s = q.stats();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.depth, 2);
    }

    #[test]
    fn enqueue_timestamps_set() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(0)).unwrap();
        assert!(q.pop().unwrap().enqueued_at.is_some());
    }

    #[test]
    fn remove_takes_out_the_matching_id_only() {
        let mut q = AdmissionQueue::new(8);
        for id in 0..4 {
            q.push(req(id)).unwrap();
        }
        assert_eq!(q.remove(2).unwrap().id, 2);
        assert!(q.remove(2).is_none(), "already removed");
        assert!(q.remove(99).is_none(), "never enqueued");
        // FIFO order of the survivors is untouched, and the counters
        // treat the removal as neither a dispatch nor a rejection.
        let rest: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(rest, vec![0, 1, 3]);
        assert_eq!(q.stats().admitted, 4);
        assert_eq!(q.stats().rejected, 0);
    }

    #[test]
    fn dispatch_counter_tracks_pops() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(0)).unwrap();
        q.push(req(1)).unwrap();
        q.pop();
        assert_eq!(q.stats().dispatched, 1);
        assert_eq!(q.stats().depth, 1);
    }
}
