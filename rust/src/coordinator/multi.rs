//! **Multi-model serving coordinator**: several ELM containers behind
//! one server, each with its own generation engine, all drawing on one
//! shared decode worker pool and one global decoded-byte budget.
//!
//! This is the serving-time framing of entropy-coded weights (Huff-LLM,
//! arXiv:2502.00922; "On the Compressibility of Quantized LLMs",
//! arXiv:2403.01384): the compressed container is a *schedulable
//! resource*, not just a storage win. Concretely:
//!
//! * every model gets its own [`Engine`] over a
//!   [`PrefetchingDigestBackend`] (continuous batching, decode-ahead
//!   prefetch, per-model `cache_*`/`prefetch_*` counters);
//! * all models share **one** [`ResidencyLedger`] — a global
//!   `--weight-budget-mb` that per-model caches draw from, so a hot
//!   model steals residency from a cold one instead of thrashing
//!   inside a static partition;
//! * all models share **one** [`PrefetchPool`] of decode workers, so
//!   decode parallelism (and decoded-but-unpublished memory overshoot)
//!   is bounded for the whole process, not per model.
//!
//! Requests are routed by the line protocol's optional `"model"` field
//! ([`crate::server::serve_multi`]); the first model is the default
//! when the field is omitted, and unknown names earn an error line.

use super::engine::{Engine, EngineConfig};
use super::request::Response;
use super::speculative::{SpecConfig, SpecStats, SPEC_K_MAX};
use crate::residency::{
    Policy, PrefetchConfig, PrefetchPool, PrefetchingDigestBackend, PrefetchingWeightSet,
    ResidencyLedger,
};
use crate::store::SegmentSource;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// One model to host: a routing name plus its segment source (a lazily
/// opened `.elm` container, or an in-memory one for tests/benches),
/// and its per-model QoS knobs under the shared ledger.
pub struct ModelSpec {
    /// Routing name (the line protocol's `"model"` field).
    pub name: String,
    /// The container the model's engine serves from.
    pub source: Arc<SegmentSource>,
    /// Minimum residency reservation: decoded bytes peers can never
    /// reclaim from this model, and headroom the shared ledger keeps
    /// committed for it even while unfilled (the `reserve-mb=N` part
    /// of `--model name=path,reserve-mb=N`). `0` = no guarantee (the
    /// PR 4 behavior).
    pub reserve_bytes: usize,
    /// Admission weight: how aggressively this model may shed peers
    /// above everyone's reserve (the `weight=W` part of the `--model`
    /// syntax). Equal weights shed only strictly-colder peers; a
    /// strictly higher weight may also shed hotter lower-weight ones.
    /// Must be finite and positive; default `1.0`.
    pub weight: f64,
}

impl ModelSpec {
    /// Spec with no reservation and the default admission weight.
    pub fn new(name: impl Into<String>, source: Arc<SegmentSource>) -> Self {
        ModelSpec {
            name: name.into(),
            source,
            reserve_bytes: 0,
            weight: 1.0,
        }
    }

    /// Attach QoS knobs (builder style).
    pub fn with_qos(mut self, reserve_bytes: usize, weight: f64) -> Self {
        self.reserve_bytes = reserve_bytes;
        self.weight = weight;
        self
    }
}

/// Construction parameters of a [`MultiModelServer`].
#[derive(Debug, Clone)]
pub struct MultiModelConfig {
    /// Global decoded-byte budget shared by every model's cache.
    pub budget_bytes: usize,
    /// Decode-ahead window per model (clamped per model to
    /// `n_layers - 1`).
    pub decode_ahead: usize,
    /// Decode worker threads in the shared pool.
    pub workers: usize,
    /// Decode batch width (slots) per engine.
    pub batch: usize,
    /// KV capacity in tokens per engine.
    pub max_seq: usize,
    /// Vocabulary size (byte-level serving uses 256).
    pub vocab: usize,
    /// Per-engine queue/sampler configuration.
    pub engine: EngineConfig,
}

impl Default for MultiModelConfig {
    fn default() -> Self {
        MultiModelConfig {
            budget_bytes: 64 << 20,
            decode_ahead: 2,
            workers: 2,
            batch: 2,
            max_seq: 64,
            vocab: 256,
            engine: EngineConfig::default(),
        }
    }
}

struct ModelEntry {
    name: String,
    engine: Engine<PrefetchingDigestBackend>,
}

/// Active speculation pairing: model indices plus the live counters.
struct SpecState {
    draft: usize,
    target: usize,
    k: usize,
    stats: SpecStats,
}

/// N models, one port: per-model engines over a shared byte ledger and
/// a shared decode worker pool. The TCP front end lives in
/// [`crate::server::serve_multi`]; this type owns the engines and the
/// routing table.
pub struct MultiModelServer {
    /// Declared first so the shared workers stop and join before any
    /// engine (and its prefetch core) is torn down.
    pool: PrefetchPool,
    ledger: Arc<ResidencyLedger>,
    entries: Vec<ModelEntry>,
    by_name: HashMap<String, usize>,
    /// Per-model decode-ahead floors (bytes), in ledger-slot order.
    /// Captured at construction so live reservation retunes can re-run
    /// the same `sum of max(floor, reserve) <= budget` check as
    /// startup.
    floors: Vec<usize>,
    /// Speculative decoding pairing, when `--speculate` is active.
    spec: Option<SpecState>,
}

impl MultiModelServer {
    /// Build one engine per spec over a shared ledger + worker pool.
    ///
    /// Fails up front when: no models, a duplicate/empty name, a
    /// non-finite or non-positive admission weight, a **sum of
    /// reservations** exceeding the global budget (a config whose
    /// guarantees cannot all be honored at once must be rejected at
    /// startup, not discovered under load), or a budget that cannot
    /// hold the **sum** of every model's `max(decode-ahead floor,
    /// reservation)` — the cross-model analogue of the single-model
    /// floor check, and what keeps "every byte committed to peers"
    /// unreachable even when every peer sits on its full reserve.
    pub fn new(specs: Vec<ModelSpec>, cfg: MultiModelConfig) -> Result<Self> {
        if specs.is_empty() {
            return Err(Error::InvalidArg(
                "multi-model server needs at least one model".into(),
            ));
        }
        let mut by_name = HashMap::new();
        for (i, spec) in specs.iter().enumerate() {
            if spec.name.is_empty() {
                return Err(Error::InvalidArg("model names must be non-empty".into()));
            }
            if by_name.insert(spec.name.clone(), i).is_some() {
                return Err(Error::InvalidArg(format!(
                    "duplicate model name {:?}",
                    spec.name
                )));
            }
            if !spec.weight.is_finite() || spec.weight <= 0.0 {
                return Err(Error::InvalidArg(format!(
                    "model {:?}: admission weight must be a positive finite number, \
                     got {}",
                    spec.name, spec.weight
                )));
            }
        }
        let reserve_sum: usize = specs
            .iter()
            .fold(0usize, |acc, s| acc.saturating_add(s.reserve_bytes));
        if reserve_sum > cfg.budget_bytes {
            return Err(Error::InvalidArg(format!(
                "residency reservations sum to {} B but the global weight budget \
                 is {} B — every reserve is a hard guarantee, so their sum must \
                 fit the budget; lower the reserves or raise --weight-budget-mb",
                reserve_sum, cfg.budget_bytes
            )));
        }
        let mut floor_sum = 0usize;
        let mut floors = Vec::with_capacity(specs.len());
        for spec in &specs {
            let window = cfg
                .decode_ahead
                .min(spec.source.n_layers().saturating_sub(1));
            let largest = spec
                .source
                .layers()
                .iter()
                .map(|m| m.n_symbols)
                .max()
                .unwrap_or(0);
            let floor = largest.saturating_mul(window + 1);
            // A model committed to its reserve still needs its decode-
            // ahead floor on top of every peer's commitment, so each
            // member contributes the larger of the two.
            floor_sum = floor_sum.saturating_add(floor.max(spec.reserve_bytes));
            floors.push(floor);
        }
        if cfg.budget_bytes < floor_sum {
            return Err(Error::InvalidArg(format!(
                "global weight budget {} B cannot hold every model's decode-ahead \
                 floor (sum of max(floor, reserve) = {} B across {} models) — \
                 lower --decode-ahead, lower the reserves, or raise the budget",
                cfg.budget_bytes,
                floor_sum,
                specs.len()
            )));
        }

        let ledger = ResidencyLedger::new(cfg.budget_bytes);
        let pcfg = PrefetchConfig {
            decode_ahead: cfg.decode_ahead,
            // No private workers: the shared pool below drives every
            // model's queue.
            workers: 0,
            policy: Policy::SegmentedLru,
        };
        let mut entries = Vec::with_capacity(specs.len());
        let mut shares = Vec::with_capacity(specs.len());
        for spec in specs {
            let ws = PrefetchingWeightSet::with_ledger_qos(
                spec.source,
                Arc::clone(&ledger),
                Vec::new(),
                pcfg,
                spec.reserve_bytes,
                spec.weight,
            )?;
            shares.push(Arc::clone(ws.shared()));
            entries.push(ModelEntry {
                name: spec.name,
                engine: Engine::new(
                    PrefetchingDigestBackend::new(ws, cfg.batch, cfg.max_seq, cfg.vocab),
                    cfg.engine.clone(),
                ),
            });
        }
        // Peer links (indexed by ledger slot = construction order) let
        // a hot model shed a cold one's residency.
        let weak: Vec<_> = shares.iter().map(Arc::downgrade).collect();
        for share in &shares {
            share.link_peers(weak.clone());
        }
        let pool = PrefetchPool::new(shares, cfg.workers);
        Ok(MultiModelServer {
            pool,
            ledger,
            entries,
            by_name,
            floors,
            spec: None,
        })
    }

    /// Turn on speculative decoding (the `--speculate
    /// draft=NAME,target=NAME,k=K` flag): requests routed to the
    /// *target* model run [`Engine::step_speculative`] with the
    /// *draft* model's backend proposing `k` greedy tokens per step;
    /// every other model (including the draft's own request traffic)
    /// keeps stepping plainly. Both names must be hosted and distinct,
    /// `k` in `1..=`[`SPEC_K_MAX`].
    pub fn enable_speculation(&mut self, cfg: &SpecConfig) -> Result<()> {
        let draft = self.resolve(Some(cfg.draft.as_str()))?;
        let target = self.resolve(Some(cfg.target.as_str()))?;
        if draft == target {
            return Err(Error::InvalidArg(
                "--speculate: draft and target must be different models".into(),
            ));
        }
        if cfg.k == 0 || cfg.k > SPEC_K_MAX {
            return Err(Error::InvalidArg(format!(
                "--speculate: k must be in 1..={SPEC_K_MAX}, got {}",
                cfg.k
            )));
        }
        self.spec = Some(SpecState {
            draft,
            target,
            k: cfg.k,
            stats: SpecStats::default(),
        });
        Ok(())
    }

    /// The active speculation pairing, if any: `(draft name, target
    /// name, k, counters)` — the source of the `{"stats":true}` line's
    /// `spec_*` family.
    pub fn speculation(&self) -> Option<(&str, &str, usize, &SpecStats)> {
        self.spec.as_ref().map(|s| {
            (
                self.entries[s.draft].name.as_str(),
                self.entries[s.target].name.as_str(),
                s.k,
                &s.stats,
            )
        })
    }

    /// One engine step for model `index`, dispatching to the
    /// speculative step when `index` is the configured speculation
    /// target (the draft model's backend is borrowed for the proposal
    /// phase; its own engine still serves its own traffic through
    /// plain steps). This is what the serving loop calls instead of
    /// `engine_mut(index).step()`.
    pub fn step_model(&mut self, index: usize) -> Result<Vec<Response>> {
        match &mut self.spec {
            Some(s) if s.target == index => {
                let (ti, di, k) = (s.target, s.draft, s.k);
                // Split borrow: the target engine and the draft backend
                // live in different `entries` cells (validated distinct
                // at enable time).
                let (target, draft) = if ti < di {
                    let (lo, hi) = self.entries.split_at_mut(di);
                    (&mut lo[ti], &mut hi[0])
                } else {
                    let (lo, hi) = self.entries.split_at_mut(ti);
                    (&mut hi[0], &mut lo[di])
                };
                target
                    .engine
                    .step_speculative(draft.engine.backend_mut(), k, &mut s.stats)
            }
            _ => self.entries[index].engine.step(),
        }
    }

    /// Hosted model count.
    pub fn n_models(&self) -> usize {
        self.entries.len()
    }

    /// Routing name of model `index`.
    pub fn name(&self, index: usize) -> &str {
        &self.entries[index].name
    }

    /// Resolve a request's optional `"model"` field to an engine index:
    /// the first (default) model when omitted, an error naming the
    /// hosted models when unknown.
    pub fn resolve(&self, model: Option<&str>) -> Result<usize> {
        match model {
            None => Ok(0),
            Some(name) => self.by_name.get(name).copied().ok_or_else(|| {
                Error::InvalidArg(format!(
                    "unknown model {name:?} (hosted: {})",
                    self.entries
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            }),
        }
    }

    /// Borrow model `index`'s engine.
    pub fn engine(&self, index: usize) -> &Engine<PrefetchingDigestBackend> {
        &self.entries[index].engine
    }

    /// Mutably borrow model `index`'s engine (submit/step).
    pub fn engine_mut(&mut self, index: usize) -> &mut Engine<PrefetchingDigestBackend> {
        &mut self.entries[index].engine
    }

    /// Cancel request `id` on model `index`'s engine (dead-waiter
    /// sweep, shutdown drain). Returns whether anything was cancelled.
    pub fn cancel(&mut self, index: usize, id: u64) -> bool {
        self.entries[index].engine.cancel(id)
    }

    /// The shared byte ledger.
    pub fn ledger(&self) -> &Arc<ResidencyLedger> {
        &self.ledger
    }

    /// Model `index`'s QoS snapshot (reservation, weight, usage, shed
    /// traffic) from the shared ledger — ledger slots are assigned in
    /// spec order, so slot `index` is model `index`.
    pub fn model_counters(&self, index: usize) -> crate::residency::ModelQosCounters {
        self.ledger.model_counters(index)
    }

    /// Re-tune residency reservations **live**, without restarting the
    /// server (the admin line's `{"reserve":{model: mb}}` verb).
    ///
    /// `updates` maps model names to new reservation byte counts;
    /// models not named keep their current reserve. The new assignment
    /// passes the exact validation `new` applies at startup — every
    /// name must be hosted, the reservations must sum within the
    /// global budget (checked atomically inside the ledger), and the
    /// budget must still hold the sum of every model's
    /// `max(decode-ahead floor, reserve)`. On any error nothing
    /// changes; on success the new floors bind immediately (peer sheds
    /// stop at the new reserve, and unfilled headroom is committed).
    pub fn retune_reserves(&self, updates: &[(String, usize)]) -> Result<()> {
        let mut slot_updates = Vec::with_capacity(updates.len());
        for (name, bytes) in updates {
            slot_updates.push((self.resolve(Some(name))?, *bytes));
        }
        // Startup's two checks, replayed against the proposed
        // assignment in the same order (last update wins when a name
        // repeats, matching the ledger's own resolution). The ledger
        // re-runs the sum check atomically inside its lock below; this
        // pre-check just earns the same error wording as `new`.
        let new_reserve = |i: usize| -> usize {
            slot_updates
                .iter()
                .rev()
                .find(|&&(slot, _)| slot == i)
                .map(|&(_, b)| b)
                .unwrap_or_else(|| self.ledger.reserve_of(i))
        };
        let budget = self.ledger.counters().budget_bytes;
        let reserve_sum = (0..self.floors.len())
            .fold(0usize, |acc, i| acc.saturating_add(new_reserve(i)));
        if reserve_sum > budget {
            return Err(Error::InvalidArg(format!(
                "residency reservations would sum to {} B but the global weight \
                 budget is {} B — every reserve is a hard guarantee, so their \
                 sum must fit the budget; lower the reserves or raise the budget",
                reserve_sum, budget
            )));
        }
        let mut floor_sum = 0usize;
        for (i, &floor) in self.floors.iter().enumerate() {
            floor_sum = floor_sum.saturating_add(floor.max(new_reserve(i)));
        }
        if budget < floor_sum {
            return Err(Error::InvalidArg(format!(
                "global weight budget {} B cannot hold every model's decode-ahead \
                 floor under the new reservations (sum of max(floor, reserve) = \
                 {} B across {} models) — lower the reserves or raise the budget",
                budget,
                floor_sum,
                self.entries.len()
            )));
        }
        self.ledger
            .set_reserves(&slot_updates)
            .map_err(Error::InvalidArg)
    }

    /// The shared decode worker pool.
    pub fn pool(&self) -> &PrefetchPool {
        &self.pool
    }

    /// Does any engine have queued or active work?
    pub fn has_work(&self) -> bool {
        self.entries.iter().any(|e| e.engine.has_work())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use crate::pipeline::synthetic_layers;
    use crate::quant::BitWidth;
    use crate::store::compress;

    fn spec(name: &str, n_layers: usize, seed: u64) -> ModelSpec {
        let layers = synthetic_layers(n_layers, seed);
        let (model, _) = compress(&layers, BitWidth::U8).unwrap();
        ModelSpec::new(name, Arc::new(SegmentSource::from_model(Arc::new(model))))
    }

    /// Whole decoded model, but never below the decode-ahead floor
    /// (default window 2 + active layer) the coordinator enforces.
    fn total_bytes(spec: &ModelSpec) -> usize {
        let largest = spec
            .source
            .layers()
            .iter()
            .map(|m| m.n_symbols)
            .max()
            .unwrap_or(0);
        spec.source.n_params().max(3 * largest)
    }

    #[test]
    fn construction_validates_names_and_budget_floor() {
        let cfg = MultiModelConfig::default();
        assert!(MultiModelServer::new(Vec::new(), cfg.clone()).is_err());

        let dup = vec![spec("a", 4, 1), spec("a", 4, 2)];
        let err = MultiModelServer::new(dup, cfg.clone()).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");

        let unnamed = vec![ModelSpec::new("", spec("x", 4, 3).source)];
        let err = MultiModelServer::new(unnamed, cfg.clone()).unwrap_err();
        assert!(err.to_string().contains("non-empty"), "{err}");

        // A budget below the summed decode-ahead floors is rejected up
        // front, naming the shortfall.
        let tiny = MultiModelConfig {
            budget_bytes: 16,
            ..cfg
        };
        let err = MultiModelServer::new(vec![spec("a", 4, 4), spec("b", 4, 5)], tiny).unwrap_err();
        assert!(err.to_string().contains("floor"), "{err}");
    }

    /// The QoS acceptance gate: a config whose reservations sum past
    /// the global budget is rejected at startup, as is a bogus weight
    /// — and a reservation that *does* fit constructs fine and
    /// surfaces in the per-model counters.
    #[test]
    fn construction_validates_reservations_and_weights() {
        let cfg = MultiModelConfig::default();
        let budget = cfg.budget_bytes;

        // Reserves summing over the budget: rejected, naming both
        // sides of the inequality.
        let over = vec![
            spec("a", 4, 20).with_qos(budget / 2 + 1, 1.0),
            spec("b", 4, 21).with_qos(budget / 2 + 1, 1.0),
        ];
        let err = MultiModelServer::new(over, cfg.clone()).unwrap_err();
        assert!(err.to_string().contains("reservations"), "{err}");
        assert!(err.to_string().contains("guarantee"), "{err}");

        // Reserve overflow (usize::MAX each) must not wrap past the
        // check.
        let wrap = vec![
            spec("a", 4, 22).with_qos(usize::MAX, 1.0),
            spec("b", 4, 23).with_qos(usize::MAX, 1.0),
        ];
        assert!(MultiModelServer::new(wrap, cfg.clone()).is_err());

        // Bad admission weights are rejected, naming the model.
        for w in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let bad = vec![spec("a", 4, 24), spec("b", 4, 25).with_qos(0, w)];
            let err = MultiModelServer::new(bad, cfg.clone()).unwrap_err();
            assert!(err.to_string().contains("weight"), "w={w}: {err}");
            assert!(err.to_string().contains("\"b\""), "w={w}: {err}");
        }

        // A legal reservation constructs and is visible per model.
        let ok = vec![
            spec("latency", 4, 26).with_qos(budget / 4, 3.0),
            spec("batch", 4, 27),
        ];
        let multi = MultiModelServer::new(ok, cfg).unwrap();
        let q0 = multi.model_counters(0);
        assert_eq!(q0.reserved_bytes, budget / 4);
        assert_eq!(q0.weight, 3.0);
        let q1 = multi.model_counters(1);
        assert_eq!(q1.reserved_bytes, 0);
        assert_eq!(q1.weight, 1.0);
        assert_eq!(multi.ledger().counters().reserved_bytes, budget / 4);
    }

    #[test]
    fn resolve_routes_default_known_and_unknown() {
        let a = spec("alpha", 4, 10);
        let b = spec("beta", 4, 11);
        let budget = total_bytes(&a) + total_bytes(&b);
        let multi = MultiModelServer::new(
            vec![a, b],
            MultiModelConfig {
                budget_bytes: budget,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        assert_eq!(multi.n_models(), 2);
        assert_eq!(multi.resolve(None).unwrap(), 0, "first model is default");
        assert_eq!(multi.resolve(Some("beta")).unwrap(), 1);
        let err = multi.resolve(Some("gamma")).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
        assert!(err.to_string().contains("alpha"), "lists hosted: {err}");
    }

    /// The tentpole acceptance at the engine level: two models served
    /// through one coordinator (shared ledger + shared pool) generate
    /// token streams bit-identical to two isolated single-model
    /// engines at the same per-model budget.
    #[test]
    fn two_models_generate_bit_identical_to_isolated_engines() {
        let a = spec("alpha", 6, 0x90);
        let b = spec("beta", 8, 0x91);
        let per_budget = |s: &ModelSpec| {
            let largest = s
                .source
                .layers()
                .iter()
                .map(|m| m.n_symbols)
                .max()
                .unwrap();
            // Tight enough to evict, high enough for the window floor.
            (total_bytes(s) / 2).max(3 * largest)
        };
        let (budget_a, budget_b) = (per_budget(&a), per_budget(&b));

        let reqs =
            |offset: u64| -> Vec<Request> {
                (0..3)
                    .map(|i| {
                        Request::greedy(offset + i, vec![5 + i as u32, 9, 2 + i as u32], 6)
                    })
                    .collect()
            };

        // Isolated reference runs, one engine per model.
        let isolated = |s: &ModelSpec, budget: usize, reqs: &[Request]| {
            let ws = PrefetchingWeightSet::new(
                Arc::clone(&s.source),
                budget,
                Vec::new(),
                PrefetchConfig {
                    decode_ahead: 2,
                    workers: 2,
                    policy: Policy::SegmentedLru,
                },
            )
            .unwrap();
            let mut engine = Engine::new(
                PrefetchingDigestBackend::new(ws, 2, 64, 256),
                EngineConfig::default(),
            );
            for r in reqs {
                engine.submit(r.clone()).unwrap();
            }
            let mut out: Vec<(u64, Vec<u32>)> = engine
                .run_to_completion(10_000)
                .unwrap()
                .into_iter()
                .map(|r| (r.id, r.tokens))
                .collect();
            out.sort();
            out
        };
        let want_a = isolated(&a, budget_a, &reqs(0));
        let want_b = isolated(&b, budget_b, &reqs(100));

        // Multi: same total budget, both models behind one coordinator,
        // requests interleaved across the two engines.
        let mut multi = MultiModelServer::new(
            vec![a, b],
            MultiModelConfig {
                budget_bytes: budget_a + budget_b,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        for (ra, rb) in reqs(0).into_iter().zip(reqs(100)) {
            multi.engine_mut(0).submit(ra).unwrap();
            multi.engine_mut(1).submit(rb).unwrap();
        }
        let mut got_a = Vec::new();
        let mut got_b = Vec::new();
        let mut steps = 0;
        while multi.has_work() && steps < 10_000 {
            for (mi, out) in [(0, &mut got_a), (1, &mut got_b)] {
                for resp in multi.engine_mut(mi).step().unwrap() {
                    out.push((resp.id, resp.tokens));
                }
            }
            steps += 1;
        }
        got_a.sort();
        got_b.sort();
        assert_eq!(got_a, want_a, "model alpha's tokens diverged under multi");
        assert_eq!(got_b, want_b, "model beta's tokens diverged under multi");

        // Shared accounting stayed within the global budget.
        let lc = multi.ledger().counters();
        assert!(lc.peak_used_bytes <= lc.budget_bytes, "{lc:?}");
        assert_eq!(lc.models, 2);
        // Both models moved their own cache counters.
        assert!(multi.engine(0).residency().unwrap().misses > 0);
        assert!(multi.engine(1).residency().unwrap().misses > 0);
    }

    /// Live reservation retune: shifting a guarantee between models
    /// applies the same startup validation (unknown names, floor sums,
    /// budget sums) and either lands atomically or changes nothing.
    #[test]
    fn retune_reserves_revalidates_and_applies_atomically() {
        let a = spec("latency", 4, 30);
        let b = spec("batch", 4, 31);
        let budget = total_bytes(&a) + total_bytes(&b);
        let half = budget / 2;
        let multi = MultiModelServer::new(
            vec![a.with_qos(half, 2.0), b],
            MultiModelConfig {
                budget_bytes: budget,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        assert_eq!(multi.model_counters(0).reserved_bytes, half);
        assert_eq!(multi.model_counters(1).reserved_bytes, 0);

        // Shift the guarantee: latency gives most of it up, batch
        // picks some up. One atomic verb, both visible after.
        multi
            .retune_reserves(&[("latency".to_string(), half / 4), ("batch".to_string(), half / 2)])
            .unwrap();
        assert_eq!(multi.model_counters(0).reserved_bytes, half / 4);
        assert_eq!(multi.model_counters(1).reserved_bytes, half / 2);
        assert_eq!(multi.ledger().counters().reserved_bytes, half / 4 + half / 2);

        // Unknown model name: refused, nothing moves.
        let err = multi.retune_reserves(&[("gamma".to_string(), 1)]).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
        assert_eq!(multi.model_counters(0).reserved_bytes, half / 4);

        // New sum past the budget: refused atomically — even though
        // the first update alone would fit.
        let err = multi
            .retune_reserves(&[("latency".to_string(), 0), ("batch".to_string(), budget + 1)])
            .unwrap_err();
        assert!(err.to_string().contains("reservations"), "{err}");
        assert!(err.to_string().contains("guarantee"), "{err}");
        assert_eq!(multi.model_counters(0).reserved_bytes, half / 4);
        assert_eq!(multi.model_counters(1).reserved_bytes, half / 2);

        // Overflow must not wrap past the check.
        assert!(multi
            .retune_reserves(&[
                ("latency".to_string(), usize::MAX),
                ("batch".to_string(), usize::MAX),
            ])
            .is_err());

        // A repeated name resolves last-wins, like the ledger.
        multi
            .retune_reserves(&[("batch".to_string(), budget + 1), ("batch".to_string(), 0)])
            .unwrap();
        assert_eq!(multi.model_counters(1).reserved_bytes, 0);
    }

    /// QoS moves *where bytes are resident*, never *what the models
    /// generate*: the same interleaved load produces bit-identical
    /// token streams with and without reservations/weights.
    #[test]
    fn qos_reservations_never_change_token_streams() {
        let run = |qos: bool| -> Vec<Vec<(u64, Vec<u32>)>> {
            let a = spec("alpha", 6, 0x92);
            let b = spec("beta", 6, 0x93);
            let budget = total_bytes(&a) + total_bytes(&b);
            let reserve_a = total_bytes(&a);
            let specs = if qos {
                vec![a.with_qos(reserve_a, 4.0), b]
            } else {
                vec![a, b]
            };
            let mut multi = MultiModelServer::new(
                specs,
                MultiModelConfig {
                    budget_bytes: budget,
                    ..MultiModelConfig::default()
                },
            )
            .unwrap();
            for i in 0..3u64 {
                multi
                    .engine_mut(0)
                    .submit(Request::greedy(i, vec![4 + i as u32, 11], 5))
                    .unwrap();
                multi
                    .engine_mut(1)
                    .submit(Request::greedy(100 + i, vec![2, 8 + i as u32], 5))
                    .unwrap();
            }
            let mut out = vec![Vec::new(), Vec::new()];
            let mut steps = 0;
            while multi.has_work() && steps < 10_000 {
                for mi in 0..2 {
                    for resp in multi.engine_mut(mi).step().unwrap() {
                        out[mi].push((resp.id, resp.tokens));
                    }
                }
                steps += 1;
            }
            for m in &mut out {
                m.sort();
            }
            let lc = multi.ledger().counters();
            assert!(lc.peak_used_bytes <= lc.budget_bytes, "{lc:?}");
            if qos {
                assert_eq!(multi.model_counters(0).reserved_bytes, reserve_a);
            }
            out
        };
        assert_eq!(run(false), run(true), "QoS changed a token stream");
    }

    #[test]
    fn enable_speculation_validates_names() {
        let a = spec("draftee", 4, 40);
        let b = spec("verifier", 4, 41);
        let budget = total_bytes(&a) + total_bytes(&b);
        let mut multi = MultiModelServer::new(
            vec![a, b],
            MultiModelConfig {
                budget_bytes: budget,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        assert!(multi.speculation().is_none());

        let err = multi
            .enable_speculation(&SpecConfig {
                draft: "ghost".into(),
                target: "verifier".into(),
                k: 4,
            })
            .unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");

        // Same model both sides must be refused even when the config
        // was built by hand rather than through `SpecConfig::parse`.
        let err = multi
            .enable_speculation(&SpecConfig {
                draft: "verifier".into(),
                target: "verifier".into(),
                k: 4,
            })
            .unwrap_err();
        assert!(err.to_string().contains("different"), "{err}");

        let err = multi
            .enable_speculation(&SpecConfig {
                draft: "draftee".into(),
                target: "verifier".into(),
                k: 0,
            })
            .unwrap_err();
        assert!(err.to_string().contains("k must be"), "{err}");
        assert!(multi.speculation().is_none(), "failed enables leave it off");

        multi
            .enable_speculation(&SpecConfig {
                draft: "draftee".into(),
                target: "verifier".into(),
                k: 4,
            })
            .unwrap();
        let (d, t, k, stats) = multi.speculation().unwrap();
        assert_eq!((d, t, k), ("draftee", "verifier", 4));
        assert_eq!(stats.steps, 0);
    }

    /// The tentpole acceptance at the coordinator level: with
    /// speculation on, the target model's streams are bit-identical to
    /// the same multi-model serve without speculation (which PR 8
    /// already pinned to isolated single-engine decode), the draft
    /// model's own traffic is untouched, and the `spec_*` counters
    /// account for every target token.
    #[test]
    fn speculative_multi_matches_plain_multi_streams() {
        let run = |spec_on: bool| {
            let d = spec("small", 4, 0x94);
            let t = spec("big", 8, 0x95);
            let budget = total_bytes(&d) + total_bytes(&t);
            let mut multi = MultiModelServer::new(
                vec![d, t],
                MultiModelConfig {
                    budget_bytes: budget,
                    ..MultiModelConfig::default()
                },
            )
            .unwrap();
            if spec_on {
                multi
                    .enable_speculation(&SpecConfig::parse("draft=small,target=big,k=4").unwrap())
                    .unwrap();
            }
            for i in 0..3u64 {
                multi
                    .engine_mut(1)
                    .submit(Request::greedy(i, vec![7 + i as u32, 3], 8))
                    .unwrap();
                multi
                    .engine_mut(0)
                    .submit(Request::greedy(100 + i, vec![1, 5 + i as u32], 5))
                    .unwrap();
            }
            let mut out = vec![Vec::new(), Vec::new()];
            let mut steps = 0;
            while multi.has_work() && steps < 10_000 {
                for mi in 0..2 {
                    for resp in multi.step_model(mi).unwrap() {
                        out[mi].push((resp.id, resp.tokens));
                    }
                }
                steps += 1;
            }
            for m in &mut out {
                m.sort();
            }
            if spec_on {
                let (_, _, _, st) = multi.speculation().unwrap();
                assert!(st.steps > 0, "no speculative steps ran: {st:?}");
                assert!(st.proposed > 0, "draft never proposed: {st:?}");
                assert_eq!(st.fallback_steps, 0, "all-greedy load fell back: {st:?}");
                let target_tokens: usize = out[1].iter().map(|(_, t)| t.len()).sum();
                assert_eq!(
                    st.emitted, target_tokens as u64,
                    "every target token must come from a speculative step"
                );
                assert!(st.emitted >= st.steps, "a step emits at least one token");
            }
            out
        };
        assert_eq!(
            run(false),
            run(true),
            "speculation changed a token stream"
        );
    }

    /// The residency half of the tentpole (and the satellite ledger
    /// test): a correlated draft+target burst — every speculative step
    /// faults both models' weight sets in the same engine step — may
    /// shed either model down **to** its reservation, never through
    /// it, under a budget tight enough to force cross-model eviction.
    #[test]
    fn speculative_burst_never_sheds_either_model_below_reserve() {
        let d = spec("small", 6, 0x96);
        let t = spec("big", 6, 0x97);
        let floor = |s: &ModelSpec| {
            3 * s
                .source
                .layers()
                .iter()
                .map(|m| m.n_symbols)
                .max()
                .unwrap()
        };
        let (rd, rt) = (floor(&d), floor(&t));
        // Tight: both reserves fit, the two full models do not.
        let budget = (rd + rt).max((total_bytes(&d) + total_bytes(&t)) * 2 / 3);
        let mut multi = MultiModelServer::new(
            vec![d.with_qos(rd, 1.0), t.with_qos(rt, 1.0)],
            MultiModelConfig {
                budget_bytes: budget,
                ..MultiModelConfig::default()
            },
        )
        .unwrap();
        multi
            .enable_speculation(&SpecConfig::parse("draft=small,target=big,k=4").unwrap())
            .unwrap();
        for i in 0..4u64 {
            multi
                .engine_mut(1)
                .submit(Request::greedy(i, vec![2 + i as u32, 9], 10))
                .unwrap();
            multi
                .engine_mut(0)
                .submit(Request::greedy(100 + i, vec![6, 1 + i as u32], 6))
                .unwrap();
        }
        let mut warmed = [false, false];
        let mut steps = 0;
        while multi.has_work() && steps < 10_000 {
            for mi in 0..2 {
                multi.step_model(mi).unwrap();
            }
            // Once a model's working set has grown past its reserve,
            // peer pressure must never push it back below.
            for (mi, reserve) in [(0usize, rd), (1usize, rt)] {
                let used = multi.model_counters(mi).used_bytes;
                if warmed[mi] {
                    assert!(
                        used >= reserve,
                        "model {mi} shed below reserve at step {steps}: \
                         used {used} < reserved {reserve}"
                    );
                } else {
                    warmed[mi] = used >= reserve;
                }
            }
            steps += 1;
        }
        assert!(warmed[0] && warmed[1], "burst never warmed both models");
        let (_, _, _, st) = multi.speculation().unwrap();
        assert!(st.steps > 0 && st.emitted > 0, "{st:?}");
        let lc = multi.ledger().counters();
        assert!(lc.peak_used_bytes <= lc.budget_bytes, "{lc:?}");
        // The budget was actually contested: at least one direction of
        // cross-model shedding fired during the burst.
        let q0 = multi.model_counters(0);
        let q1 = multi.model_counters(1);
        assert!(
            q0.shed_by_peers + q1.shed_by_peers > 0,
            "budget never contested — loosen it: {q0:?} {q1:?}"
        );
    }
}
