//! Host-side KV mirror and slot splicing.
//!
//! The decode executable works on batched caches `[L, B, MS, H, HD]`.
//! Each batch index ("slot") belongs to one in-flight sequence. Prefill
//! produces a single-slot cache `[L, 1, MS, H, HD]`; admitting a request
//! splices that into the batch at its slot. The mirror tracks a host
//! copy so splices don't need a device read-modify-write round trip when
//! several admissions happen between decode steps.
//!
//! Correctness note on pad garbage (see python model.prefill docs): the
//! prefill cache holds garbage at positions ≥ prompt length, but decode
//! writes position `pos` *before* attending over `[0, pos]`, and `pos`
//! starts at the prompt length — so garbage is always overwritten before
//! it becomes visible.

use crate::{Error, Result};

/// Host mirror of the batched KV caches.
#[derive(Debug, Clone)]
pub struct KvMirror {
    /// K cache `[L, B, MS, H, HD]`, row-major.
    pub k: Vec<f32>,
    /// V cache, same layout.
    pub v: Vec<f32>,
    layers: usize,
    batch: usize,
    slot_stride: usize,
    layer_stride: usize,
    /// True when the host copy is newer than the device copy.
    pub dirty: bool,
}

impl KvMirror {
    /// Zero-initialized mirror for `[layers, batch, max_seq, heads, head_dim]`.
    pub fn new(layers: usize, batch: usize, max_seq: usize, heads: usize, head_dim: usize) -> Self {
        let slot_stride = max_seq * heads * head_dim;
        let layer_stride = batch * slot_stride;
        KvMirror {
            k: vec![0.0; layers * layer_stride],
            v: vec![0.0; layers * layer_stride],
            layers,
            batch,
            slot_stride,
            layer_stride,
            dirty: true,
        }
    }

    /// Total element count of one cache.
    pub fn numel(&self) -> usize {
        self.k.len()
    }

    /// Splice a single-slot prefill cache `[L, 1, MS, H, HD]` into
    /// batch slot `slot`.
    pub fn splice_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
        if slot >= self.batch {
            return Err(Error::InvalidArg(format!(
                "slot {slot} out of range (batch {})",
                self.batch
            )));
        }
        let expect = self.layers * self.slot_stride;
        if k1.len() != expect || v1.len() != expect {
            return Err(Error::InvalidArg(format!(
                "single-slot kv has {} elements, want {expect}",
                k1.len()
            )));
        }
        for l in 0..self.layers {
            let src = l * self.slot_stride..(l + 1) * self.slot_stride;
            let dst_base = l * self.layer_stride + slot * self.slot_stride;
            self.k[dst_base..dst_base + self.slot_stride].copy_from_slice(&k1[src.clone()]);
            self.v[dst_base..dst_base + self.slot_stride].copy_from_slice(&v1[src]);
        }
        self.dirty = true;
        Ok(())
    }

    /// Replace the whole mirror from device downloads (after decode
    /// steps, before a splice).
    pub fn refresh_from(&mut self, k: Vec<f32>, v: Vec<f32>) -> Result<()> {
        if k.len() != self.k.len() || v.len() != self.v.len() {
            return Err(Error::InvalidArg("kv refresh size mismatch".into()));
        }
        self.k = k;
        self.v = v;
        self.dirty = false;
        Ok(())
    }

    /// Copy batch slot `slot` out as a single-slot cache
    /// `[L, 1, MS, H, HD]` — the exact inverse of [`splice_slot`],
    /// so `splice_slot(s, &extract_slot(s))` is an identity. This is
    /// how a preempted request's KV state leaves the batch: extract on
    /// preemption, splice back on resume (possibly into a different
    /// slot).
    ///
    /// [`splice_slot`]: KvMirror::splice_slot
    pub fn extract_slot(&self, slot: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        if slot >= self.batch {
            return Err(Error::InvalidArg(format!(
                "slot {slot} out of range (batch {})",
                self.batch
            )));
        }
        let n = self.layers * self.slot_stride;
        let mut k1 = Vec::with_capacity(n);
        let mut v1 = Vec::with_capacity(n);
        for l in 0..self.layers {
            let base = l * self.layer_stride + slot * self.slot_stride;
            k1.extend_from_slice(&self.k[base..base + self.slot_stride]);
            v1.extend_from_slice(&self.v[base..base + self.slot_stride]);
        }
        Ok((k1, v1))
    }

    /// Read back one slot (testing / debugging).
    pub fn slot_k(&self, slot: usize, layer: usize) -> &[f32] {
        let base = layer * self.layer_stride + slot * self.slot_stride;
        &self.k[base..base + self.slot_stride]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splice_writes_only_target_slot() {
        let mut m = KvMirror::new(2, 3, 4, 2, 2); // L=2,B=3,MS=4,H=2,HD=2
        let per_slot = 2 * 4 * 2 * 2; // L * MS*H*HD
        let k1: Vec<f32> = (0..per_slot).map(|i| i as f32 + 1.0).collect();
        let v1: Vec<f32> = (0..per_slot).map(|i| -(i as f32) - 1.0).collect();
        m.splice_slot(1, &k1, &v1).unwrap();
        // Slot 1 layer 0 data matches the first L-stride of k1.
        assert_eq!(m.slot_k(1, 0), &k1[..16]);
        assert_eq!(m.slot_k(1, 1), &k1[16..32]);
        // Slots 0 and 2 untouched.
        assert!(m.slot_k(0, 0).iter().all(|&x| x == 0.0));
        assert!(m.slot_k(2, 1).iter().all(|&x| x == 0.0));
        assert!(m.dirty);
    }

    #[test]
    fn splice_rejects_bad_slot_and_size() {
        let mut m = KvMirror::new(1, 2, 4, 1, 2);
        assert!(m.splice_slot(5, &[], &[]).is_err());
        assert!(m.splice_slot(0, &[0.0; 3], &[0.0; 3]).is_err());
    }

    #[test]
    fn extract_inverts_splice() {
        let mut m = KvMirror::new(2, 3, 4, 2, 2);
        let per_slot = 2 * 4 * 2 * 2;
        let k1: Vec<f32> = (0..per_slot).map(|i| i as f32 + 1.0).collect();
        let v1: Vec<f32> = (0..per_slot).map(|i| -(i as f32) - 1.0).collect();
        m.splice_slot(2, &k1, &v1).unwrap();
        let (ek, ev) = m.extract_slot(2).unwrap();
        assert_eq!(ek, k1);
        assert_eq!(ev, v1);
        // Untouched slots extract as zeros; bad slot is refused.
        let (zk, _) = m.extract_slot(0).unwrap();
        assert!(zk.iter().all(|&x| x == 0.0));
        assert!(m.extract_slot(3).is_err());
    }

    #[test]
    fn refresh_clears_dirty() {
        let mut m = KvMirror::new(1, 1, 2, 1, 1);
        let n = m.numel();
        m.refresh_from(vec![1.0; n], vec![2.0; n]).unwrap();
        assert!(!m.dirty);
        assert_eq!(m.k[0], 1.0);
        assert!(m.refresh_from(vec![], vec![]).is_err());
    }
}
