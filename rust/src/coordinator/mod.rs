//! L3 serving coordinator: request queue, continuous batcher, KV slot
//! management, sampling, and the generation engine (paper §IV's edge
//! inference loop, built like a miniature vLLM-style router).
//!
//! Structure:
//!
//! * [`request`] — request/response types + timing accounting;
//! * [`backend`] — the [`Backend`](backend::Backend) trait the engine
//!   drives: a PJRT implementation ([`backend::PjrtBackend`]) for
//!   production and a deterministic mock for hermetic engine tests;
//! * [`kv`] — host-side KV mirror + slot splicing/extraction;
//! * [`batcher`] — bounded priority admission queue with aging,
//!   deadlines, and stats;
//! * [`sampler`] — greedy / temperature / top-k sampling;
//! * [`engine`] — the step loop: admit → prefill → batched decode →
//!   sample → retire, with continuous slot refill;
//! * [`multi`] — the multi-model coordinator: one engine per hosted
//!   model, all drawing on a shared decode worker pool and one global
//!   weight budget ([`MultiModelServer`]);
//! * [`speculative`] — draft-proposes / target-verifies speculative
//!   decoding across two co-resident models, with the bit-exact
//!   greedy-equivalent acceptance rule.

#![warn(missing_docs)]

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod kv;
pub mod multi;
pub mod request;
pub mod sampler;
pub mod speculative;

pub use backend::{
    digest_decode_next, digest_f32_entry, digest_prefill_next, digest_quant_entry,
    digest_weights, fnv1a64, Backend, BackendCfg, DigestBackend, MockBackend, PjrtBackend,
    FNV1A64_INIT,
};
pub use batcher::{AdmissionQueue, QueueStats};
pub use engine::{Engine, EngineConfig, EngineStats};
pub use kv::KvMirror;
pub use multi::{ModelSpec, MultiModelConfig, MultiModelServer};
pub use request::{Request, Response, ResumeState, Timing, PRIORITY_MAX, PRIORITY_MIN};
pub use sampler::{SampleCfg, Sampler};
pub use speculative::{accept_longest_prefix, SpecConfig, SpecStats, SPEC_K_MAX};
