//! The execution backend the engine drives.
//!
//! [`Backend`] abstracts "prefill one prompt" and "decode one batched
//! step" so the engine's batching/slot logic is testable without PJRT
//! artifacts ([`MockBackend`]) and production runs on the AOT
//! executables ([`PjrtBackend`]).

use super::kv::KvMirror;
use crate::quant::QuantizedTensor;
use crate::residency::{CacheCounters, PrefetchCounters};
use crate::runtime::{ModelRuntime, PrefillOut, WeightSet};
use crate::tensor::TensorF32;
use crate::Result;

/// Shape constants the engine needs from a backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendCfg {
    /// Decode batch width (slot count).
    pub batch: usize,
    /// KV capacity in tokens.
    pub max_seq: usize,
    /// Prefill prompt buffer length.
    pub prefill_len: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

/// Engine-facing execution interface.
pub trait Backend {
    /// Shape constants.
    fn cfg(&self) -> BackendCfg;

    /// Run one prompt; returns (logits `[vocab]`, single-slot K, V).
    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;

    /// Install a prefilled sequence into batch slot `slot`.
    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()>;

    /// One decode step over all slots; returns logits `[batch, vocab]`
    /// flattened row-major. KV state advances internally.
    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>>;

    /// Weight-residency cache counters, when this backend serves
    /// weights through a [`crate::residency::WeightCache`]
    /// (`None` for fully-resident backends). The engine surfaces these
    /// in the server's `{"stats":true}` admin line.
    fn residency(&self) -> Option<CacheCounters> {
        None
    }

    /// Decode-ahead prefetch counters, when this backend overlaps
    /// layer decode with token compute
    /// ([`crate::residency::PrefetchingDigestBackend`]; `None`
    /// otherwise). Surfaced as the `prefetch_*` fields of the server's
    /// `{"stats":true}` admin line.
    fn prefetch(&self) -> Option<PrefetchCounters> {
        None
    }

    /// Extract batch slot `slot`'s KV state so a preempted request can
    /// later resume through [`Backend::set_slot`] bit-identically.
    /// Backends whose generation carries no per-slot KV state (the
    /// digest family) return `Ok(None)`: the preempted request resumes
    /// from its token prefix alone.
    fn take_slot(&mut self, _slot: usize) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(None)
    }

    /// Stateless batched greedy evaluation for speculative decoding:
    /// for each row `i`, return the argmax next token the model emits
    /// after `tokens[i]` at sequence position `pos[i]`, **without**
    /// reading or advancing any per-slot KV state. Rows are arbitrary
    /// `(token, pos)` pairs — they need not correspond to live batch
    /// slots — which is what lets one call verify a whole proposal
    /// block (`k + 1` rows per speculating slot) or advance a draft
    /// model's proposal chain one token across every slot at once.
    ///
    /// Backends whose decode depends on slot-bound KV state (the PJRT
    /// backend) return `Ok(None)`: they cannot evaluate rows detached
    /// from their slots, and the engine falls back to plain per-token
    /// decode instead of speculating. Digest-family backends implement
    /// it as one full weight pass per call (same residency pressure as
    /// a decode step) followed by the pure per-row next-token map, so
    /// speculation exercises the residency/ledger machinery exactly
    /// like real decode traffic.
    fn argmax_rows(&mut self, _tokens: &[u32], _pos: &[u32]) -> Result<Option<Vec<u32>>> {
        Ok(None)
    }
}

// ------------------------------------------------------------------- PJRT

/// Production backend over the AOT PJRT executables.
///
/// KV caches live on device between steps; the host [`KvMirror`] is
/// refreshed only when a slot must be spliced (admission), which is the
/// continuous-batching slow path.
pub struct PjrtBackend {
    rt: ModelRuntime,
    mirror: KvMirror,
    device_kv: Option<(crate::runtime::DeviceBuffer, crate::runtime::DeviceBuffer)>,
}

impl PjrtBackend {
    /// Wrap a loaded runtime.
    pub fn new(rt: ModelRuntime) -> Self {
        let c = rt.config().clone();
        let mirror = KvMirror::new(c.n_layers, c.decode_batch, c.max_seq, c.n_heads, c.head_dim);
        PjrtBackend {
            rt,
            mirror,
            device_kv: None,
        }
    }

    /// Access the underlying runtime (for eval tooling).
    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }
}

impl Backend for PjrtBackend {
    fn cfg(&self) -> BackendCfg {
        let c = self.rt.config();
        BackendCfg {
            batch: c.decode_batch,
            max_seq: c.max_seq,
            prefill_len: c.prefill_len,
            vocab: c.vocab,
        }
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let PrefillOut {
            logits,
            k_cache,
            v_cache,
        } = self.rt.prefill(prompt)?;
        Ok((logits, k_cache, v_cache))
    }

    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
        // Bring the device state home first (other slots are mid-flight).
        if let Some((kb, vb)) = self.device_kv.take() {
            let (k, v) = self.rt.download_kv(&kb, &vb)?;
            self.mirror.refresh_from(k, v)?;
        }
        self.mirror.splice_slot(slot, k1, v1)?;
        Ok(())
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        if self.mirror.dirty || self.device_kv.is_none() {
            let (kb, vb) = self.rt.upload_kv(&self.mirror.k, &self.mirror.v)?;
            self.device_kv = Some((kb, vb));
            self.mirror.dirty = false;
        }
        let (kb, vb) = self.device_kv.take().expect("kv uploaded above");
        let out = self.rt.decode_step(tokens, pos, &kb, &vb)?;
        self.device_kv = Some((out.k_cache, out.v_cache));
        Ok(out.logits)
    }

    fn take_slot(&mut self, slot: usize) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        // Same slow path as set_slot: bring the device state home so
        // the mirror sees the slot's current KV, then copy it out.
        if let Some((kb, vb)) = self.device_kv.take() {
            let (k, v) = self.rt.download_kv(&kb, &vb)?;
            self.mirror.refresh_from(k, v)?;
        }
        Ok(Some(self.mirror.extract_slot(slot)?))
    }
}

// ------------------------------------------------------------------- mock

/// Deterministic fake backend for engine unit tests.
///
/// Prefill "logits" put all mass on `(sum(prompt) + 1) % vocab`; decode
/// advances each slot's token by `slot + 1` (mod vocab). KV contents are
/// slot-tagged so tests can verify splicing.
pub struct MockBackend {
    /// Shape constants.
    pub cfg: BackendCfg,
    layers: usize,
    heads: usize,
    head_dim: usize,
    /// Decode steps executed.
    pub steps: usize,
    /// Prefills executed.
    pub prefills: usize,
    /// Mirror (public for test inspection).
    pub mirror: KvMirror,
}

impl MockBackend {
    /// Mock with small default shapes.
    pub fn new(batch: usize, max_seq: usize, vocab: usize) -> Self {
        let (layers, heads, head_dim) = (2, 2, 4);
        MockBackend {
            cfg: BackendCfg {
                batch,
                max_seq,
                prefill_len: max_seq / 2,
                vocab,
            },
            layers,
            heads,
            head_dim,
            steps: 0,
            prefills: 0,
            mirror: KvMirror::new(layers, batch, max_seq, heads, head_dim),
        }
    }

    fn onehot(&self, tok: u32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.cfg.vocab];
        l[(tok as usize) % self.cfg.vocab] = 10.0;
        l
    }
}

impl Backend for MockBackend {
    fn cfg(&self) -> BackendCfg {
        self.cfg
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.prefills += 1;
        let next = (prompt.iter().sum::<u32>() + 1) % self.cfg.vocab as u32;
        let n = self.layers * self.cfg.max_seq * self.heads * self.head_dim;
        let tag = prompt.first().copied().unwrap_or(0) as f32;
        Ok((self.onehot(next), vec![tag; n], vec![-tag; n]))
    }

    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
        self.mirror.splice_slot(slot, k1, v1)
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), self.cfg.batch);
        assert_eq!(pos.len(), self.cfg.batch);
        self.steps += 1;
        let mut out = Vec::with_capacity(self.cfg.batch * self.cfg.vocab);
        for (slot, &t) in tokens.iter().enumerate() {
            let next = (t + slot as u32 + 1) % self.cfg.vocab as u32;
            out.extend_from_slice(&self.onehot(next));
        }
        Ok(out)
    }

    fn take_slot(&mut self, slot: usize) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(Some(self.mirror.extract_slot(slot)?))
    }
}

// ----------------------------------------------------------------- digest

/// FNV-1a 64-bit offset basis (pair with [`fnv1a64`]).
pub const FNV1A64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a 64-bit fold step: feed `bytes` into state `h`. The single
/// FNV implementation in the crate — [`digest_weights`] and the benches
/// both build on it so the constants can never drift apart.
pub fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Fold one named quantized tensor into a weight digest. Every
/// variable-length field is length-prefixed so the byte stream is an
/// injective encoding — without the prefixes, name bytes could
/// masquerade as dim/data bytes and two different sets could digest
/// equal by construction. Exposed so bounded-memory walkers
/// ([`crate::residency::ResidentWeightSet::digest`]) reproduce
/// [`digest_weights`] exactly without materializing the whole set.
pub fn digest_quant_entry(mut h: u64, name: &str, q: &QuantizedTensor) -> u64 {
    h = fnv1a64(h, &(name.len() as u64).to_le_bytes());
    h = fnv1a64(h, name.as_bytes());
    let dims = q.symbols.shape().dims();
    h = fnv1a64(h, &(dims.len() as u64).to_le_bytes());
    for &d in dims {
        h = fnv1a64(h, &(d as u64).to_le_bytes());
    }
    h = fnv1a64(h, &(q.symbols.data().len() as u64).to_le_bytes());
    h = fnv1a64(h, q.symbols.data());
    h = fnv1a64(h, &[q.params.scheme.tag(), q.params.bits.bits() as u8]);
    h = fnv1a64(h, &q.params.scale.to_le_bytes());
    h = fnv1a64(h, &q.params.zero_point.to_le_bytes());
    h
}

/// Fold one named fp32 tensor into a weight digest (see
/// [`digest_quant_entry`] for the injectivity argument).
pub fn digest_f32_entry(mut h: u64, name: &str, t: &TensorF32) -> u64 {
    h = fnv1a64(h, &(name.len() as u64).to_le_bytes());
    h = fnv1a64(h, name.as_bytes());
    let dims = t.shape().dims();
    h = fnv1a64(h, &(dims.len() as u64).to_le_bytes());
    for &d in dims {
        h = fnv1a64(h, &(d as u64).to_le_bytes());
    }
    h = fnv1a64(h, &(t.data().len() as u64).to_le_bytes());
    for &x in t.data() {
        h = fnv1a64(h, &x.to_le_bytes());
    }
    h
}

/// FNV-1a digest over every tensor of a [`WeightSet`] — names sorted,
/// so the digest is independent of *arrival order* but sensitive to
/// every symbol, shape, and quantization parameter. Two weight sets
/// digest equal iff they hold bit-identical weights, which is exactly
/// the property the streaming-vs-eager losslessness tests assert.
pub fn digest_weights(ws: &WeightSet) -> u64 {
    let mut h: u64 = FNV1A64_INIT;
    let mut qnames: Vec<&String> = ws.quants.keys().collect();
    qnames.sort();
    h = fnv1a64(h, &(qnames.len() as u64).to_le_bytes());
    for name in qnames {
        h = digest_quant_entry(h, name, &ws.quants[name]);
    }
    let mut fnames: Vec<&String> = ws.f32s.keys().collect();
    fnames.sort();
    h = fnv1a64(h, &(fnames.len() as u64).to_le_bytes());
    for name in fnames {
        h = digest_f32_entry(h, name, &ws.f32s[name]);
    }
    h
}

/// Next-token index a digest-driven backend emits for a whole prompt
/// (prefill). Pure: the single source of truth shared by
/// [`DigestBackend`] and the residency-serving
/// [`crate::residency::ResidentDigestBackend`], so their generations
/// agree token-for-token whenever their weight digests agree.
pub fn digest_prefill_next(digest: u64, prompt: &[u32], vocab: usize) -> u64 {
    let mut h = digest;
    for &t in prompt {
        h = h.rotate_left(7) ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }
    h % vocab as u64
}

/// Next-token index for one decode lane of a digest-driven backend
/// (see [`digest_prefill_next`]). A function of the sequence state
/// (last token, position) and the weights only — never of the physical
/// batch slot, just like real transformer logits. That invariance is
/// what lets a preempted request resume in a *different* slot and still
/// generate bit-identically.
pub fn digest_decode_next(digest: u64, token: u32, pos: u32, vocab: usize) -> u64 {
    let mixed = digest.rotate_left(9)
        ^ (token as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((pos as u64) << 20);
    mixed % vocab as u64
}

/// Deterministic backend whose generation is a pure function of a
/// weight digest: two `DigestBackend`s generate identical tokens iff
/// their weight sets are bit-identical. Stands in for the PJRT backend
/// in token-level losslessness tests (eager vs. streaming load) and in
/// benches on hosts without the real runtime.
pub struct DigestBackend {
    /// Shape constants.
    pub cfg: BackendCfg,
    digest: u64,
    /// Decode steps executed.
    pub steps: usize,
    /// Prefills executed.
    pub prefills: usize,
}

impl DigestBackend {
    /// Backend over a weight set (digest computed here).
    pub fn from_weights(ws: &WeightSet, batch: usize, max_seq: usize, vocab: usize) -> Self {
        Self::with_digest(digest_weights(ws), batch, max_seq, vocab)
    }

    /// Backend over a precomputed digest.
    pub fn with_digest(digest: u64, batch: usize, max_seq: usize, vocab: usize) -> Self {
        DigestBackend {
            cfg: BackendCfg {
                batch,
                max_seq,
                prefill_len: (max_seq / 2).max(1),
                vocab,
            },
            digest,
            steps: 0,
            prefills: 0,
        }
    }

    /// The weight digest driving generation.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    fn onehot(&self, tok: u64) -> Vec<f32> {
        let mut l = vec![0.0f32; self.cfg.vocab];
        l[(tok % self.cfg.vocab as u64) as usize] = 10.0;
        l
    }
}

impl Backend for DigestBackend {
    fn cfg(&self) -> BackendCfg {
        self.cfg
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.prefills += 1;
        let next = digest_prefill_next(self.digest, prompt, self.cfg.vocab);
        let kv = vec![next as f32; 8];
        Ok((self.onehot(next), kv.clone(), kv))
    }

    fn set_slot(&mut self, _slot: usize, _k1: &[f32], _v1: &[f32]) -> Result<()> {
        // Generation is digest-driven; there is no KV state to splice.
        Ok(())
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), self.cfg.batch);
        assert_eq!(pos.len(), self.cfg.batch);
        self.steps += 1;
        let mut out = Vec::with_capacity(self.cfg.batch * self.cfg.vocab);
        for (&t, &p) in tokens.iter().zip(pos) {
            out.extend_from_slice(
                &self.onehot(digest_decode_next(self.digest, t, p, self.cfg.vocab)),
            );
        }
        Ok(out)
    }

    fn argmax_rows(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Option<Vec<u32>>> {
        self.steps += 1;
        Ok(Some(
            tokens
                .iter()
                .zip(pos)
                .map(|(&t, &p)| digest_decode_next(self.digest, t, p, self.cfg.vocab) as u32)
                .collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut b = MockBackend::new(2, 16, 32);
        let (l1, k1, _) = b.prefill(&[3, 4]).unwrap();
        let (l2, _, _) = b.prefill(&[3, 4]).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(k1[0], 3.0);
        assert_eq!(b.prefills, 2);
    }

    #[test]
    fn mock_decode_advances_per_slot() {
        let mut b = MockBackend::new(2, 16, 32);
        let logits = b.decode(&[5, 5], &[0, 0]).unwrap();
        let row = |s: usize| &logits[s * 32..(s + 1) * 32];
        assert_eq!(crate::coordinator::sampler::argmax(row(0)), 6);
        assert_eq!(crate::coordinator::sampler::argmax(row(1)), 7);
    }

    fn sample_weightset() -> WeightSet {
        use crate::quant::{quantize_mixed, BitWidth};
        use crate::tensor::TensorF32;
        let mut ws = WeightSet::begin_streaming(vec![(
            "ln.w".into(),
            TensorF32::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        )]);
        for i in 0..3 {
            let t = TensorF32::new(
                vec![8],
                (0..8).map(|j| (i * 8 + j) as f32 * 0.01 - 0.1).collect(),
            )
            .unwrap();
            ws.insert_quantized(format!("l{i}"), quantize_mixed(&t, BitWidth::U8));
        }
        ws
    }

    #[test]
    fn digest_is_order_independent_but_content_sensitive() {
        let a = sample_weightset();
        let b = sample_weightset();
        assert_eq!(digest_weights(&a), digest_weights(&b));

        // Same layers inserted in reverse order digest identically.
        let mut rev = WeightSet::begin_streaming(vec![(
            "ln.w".into(),
            crate::tensor::TensorF32::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        )]);
        let mut names: Vec<String> = a.quants.keys().cloned().collect();
        names.sort();
        for name in names.iter().rev() {
            rev.insert_quantized(name.clone(), a.quants[name].clone());
        }
        assert_eq!(digest_weights(&a), digest_weights(&rev));

        // Flipping one symbol changes the digest.
        let mut c = sample_weightset();
        let q = c.quants.get_mut("l1").unwrap();
        let mut data = q.symbols.data().to_vec();
        data[0] ^= 1;
        q.symbols = crate::tensor::TensorU8::new(q.symbols.shape().clone(), data).unwrap();
        assert_ne!(digest_weights(&a), digest_weights(&c));
    }

    #[test]
    fn digest_backend_tokens_depend_only_on_digest() {
        let ws = sample_weightset();
        let mut b1 = DigestBackend::from_weights(&ws, 2, 16, 64);
        let mut b2 = DigestBackend::from_weights(&ws, 2, 16, 64);
        let (l1, _, _) = b1.prefill(&[3, 4, 5]).unwrap();
        let (l2, _, _) = b2.prefill(&[3, 4, 5]).unwrap();
        assert_eq!(l1, l2);
        let d1 = b1.decode(&[5, 9], &[1, 2]).unwrap();
        let d2 = b2.decode(&[5, 9], &[1, 2]).unwrap();
        assert_eq!(d1, d2);

        let mut other = DigestBackend::with_digest(b1.digest() ^ 1, 2, 16, 64);
        let (l3, _, _) = other.prefill(&[3, 4, 5]).unwrap();
        assert_ne!(l1, l3, "digest must steer generation");
    }

    #[test]
    fn digest_decode_ignores_physical_slot() {
        // Two lanes at the same (token, pos) must produce identical
        // logits rows: sequence state, not slot index, drives the next
        // token — the invariant preemptive slot reassignment rests on.
        let mut b = DigestBackend::with_digest(0xABCD, 2, 16, 64);
        let logits = b.decode(&[7, 7], &[3, 3]).unwrap();
        assert_eq!(logits[..64], logits[64..]);
    }

    #[test]
    fn argmax_rows_matches_decode_argmax_row_for_row() {
        // The verification seam must agree with plain decode on every
        // (token, pos) pair — that identity is what makes speculative
        // acceptance greedy-equivalent.
        let mut b = DigestBackend::with_digest(0xFEED, 2, 16, 64);
        let tokens = [7u32, 41];
        let pos = [3u32, 9];
        let logits = b.decode(&tokens, &pos).unwrap();
        let rows = b.argmax_rows(&tokens, &pos).unwrap().expect("digest verifies");
        for (i, &r) in rows.iter().enumerate() {
            let row = &logits[i * 64..(i + 1) * 64];
            assert_eq!(r as usize, crate::coordinator::sampler::argmax(row));
        }
        // Rows are slot-free: lengths other than the batch width work.
        let one = b.argmax_rows(&[7], &[3]).unwrap().unwrap();
        assert_eq!(one[0], rows[0]);
    }

    #[test]
    fn kv_bound_backends_decline_argmax_rows() {
        // MockBackend's decode is deliberately slot-dependent, so it
        // keeps the default decline: speculation falls back to plain
        // decode rather than accepting slot-skewed verification.
        let mut b = MockBackend::new(2, 16, 32);
        assert!(b.argmax_rows(&[1, 2], &[0, 0]).unwrap().is_none());
    }

    #[test]
    fn mock_take_slot_round_trips_through_set_slot() {
        let mut b = MockBackend::new(2, 16, 32);
        let (_, k1, v1) = b.prefill(&[9, 2]).unwrap();
        b.set_slot(1, &k1, &v1).unwrap();
        let (ek, ev) = b.take_slot(1).unwrap().expect("mock mirrors KV");
        assert_eq!(ek, k1);
        assert_eq!(ev, v1);
        // Splicing the extracted state back reproduces the mirror.
        b.set_slot(1, &ek, &ev).unwrap();
        let (ek2, ev2) = b.take_slot(1).unwrap().unwrap();
        assert_eq!((ek2, ev2), (ek, ev));
    }
}
