//! The execution backend the engine drives.
//!
//! [`Backend`] abstracts "prefill one prompt" and "decode one batched
//! step" so the engine's batching/slot logic is testable without PJRT
//! artifacts ([`MockBackend`]) and production runs on the AOT
//! executables ([`PjrtBackend`]).

use super::kv::KvMirror;
use crate::runtime::{ModelRuntime, PrefillOut};
use crate::Result;

/// Shape constants the engine needs from a backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendCfg {
    /// Decode batch width (slot count).
    pub batch: usize,
    /// KV capacity in tokens.
    pub max_seq: usize,
    /// Prefill prompt buffer length.
    pub prefill_len: usize,
    /// Vocabulary size.
    pub vocab: usize,
}

/// Engine-facing execution interface.
pub trait Backend {
    /// Shape constants.
    fn cfg(&self) -> BackendCfg;

    /// Run one prompt; returns (logits `[vocab]`, single-slot K, V).
    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;

    /// Install a prefilled sequence into batch slot `slot`.
    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()>;

    /// One decode step over all slots; returns logits `[batch, vocab]`
    /// flattened row-major. KV state advances internally.
    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>>;
}

// ------------------------------------------------------------------- PJRT

/// Production backend over the AOT PJRT executables.
///
/// KV caches live on device between steps; the host [`KvMirror`] is
/// refreshed only when a slot must be spliced (admission), which is the
/// continuous-batching slow path.
pub struct PjrtBackend {
    rt: ModelRuntime,
    mirror: KvMirror,
    device_kv: Option<(crate::runtime::DeviceBuffer, crate::runtime::DeviceBuffer)>,
}

impl PjrtBackend {
    /// Wrap a loaded runtime.
    pub fn new(rt: ModelRuntime) -> Self {
        let c = rt.config().clone();
        let mirror = KvMirror::new(c.n_layers, c.decode_batch, c.max_seq, c.n_heads, c.head_dim);
        PjrtBackend {
            rt,
            mirror,
            device_kv: None,
        }
    }

    /// Access the underlying runtime (for eval tooling).
    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }
}

impl Backend for PjrtBackend {
    fn cfg(&self) -> BackendCfg {
        let c = self.rt.config();
        BackendCfg {
            batch: c.decode_batch,
            max_seq: c.max_seq,
            prefill_len: c.prefill_len,
            vocab: c.vocab,
        }
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let PrefillOut {
            logits,
            k_cache,
            v_cache,
        } = self.rt.prefill(prompt)?;
        Ok((logits, k_cache, v_cache))
    }

    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
        // Bring the device state home first (other slots are mid-flight).
        if let Some((kb, vb)) = self.device_kv.take() {
            let (k, v) = self.rt.download_kv(&kb, &vb)?;
            self.mirror.refresh_from(k, v)?;
        }
        self.mirror.splice_slot(slot, k1, v1)?;
        Ok(())
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        if self.mirror.dirty || self.device_kv.is_none() {
            let (kb, vb) = self.rt.upload_kv(&self.mirror.k, &self.mirror.v)?;
            self.device_kv = Some((kb, vb));
            self.mirror.dirty = false;
        }
        let (kb, vb) = self.device_kv.take().expect("kv uploaded above");
        let out = self.rt.decode_step(tokens, pos, &kb, &vb)?;
        self.device_kv = Some((out.k_cache, out.v_cache));
        Ok(out.logits)
    }
}

// ------------------------------------------------------------------- mock

/// Deterministic fake backend for engine unit tests.
///
/// Prefill "logits" put all mass on `(sum(prompt) + 1) % vocab`; decode
/// advances each slot's token by `slot + 1` (mod vocab). KV contents are
/// slot-tagged so tests can verify splicing.
pub struct MockBackend {
    /// Shape constants.
    pub cfg: BackendCfg,
    layers: usize,
    heads: usize,
    head_dim: usize,
    /// Decode steps executed.
    pub steps: usize,
    /// Prefills executed.
    pub prefills: usize,
    /// Mirror (public for test inspection).
    pub mirror: KvMirror,
}

impl MockBackend {
    /// Mock with small default shapes.
    pub fn new(batch: usize, max_seq: usize, vocab: usize) -> Self {
        let (layers, heads, head_dim) = (2, 2, 4);
        MockBackend {
            cfg: BackendCfg {
                batch,
                max_seq,
                prefill_len: max_seq / 2,
                vocab,
            },
            layers,
            heads,
            head_dim,
            steps: 0,
            prefills: 0,
            mirror: KvMirror::new(layers, batch, max_seq, heads, head_dim),
        }
    }

    fn onehot(&self, tok: u32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.cfg.vocab];
        l[(tok as usize) % self.cfg.vocab] = 10.0;
        l
    }
}

impl Backend for MockBackend {
    fn cfg(&self) -> BackendCfg {
        self.cfg
    }

    fn prefill(&mut self, prompt: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        self.prefills += 1;
        let next = (prompt.iter().sum::<u32>() + 1) % self.cfg.vocab as u32;
        let n = self.layers * self.cfg.max_seq * self.heads * self.head_dim;
        let tag = prompt.first().copied().unwrap_or(0) as f32;
        Ok((self.onehot(next), vec![tag; n], vec![-tag; n]))
    }

    fn set_slot(&mut self, slot: usize, k1: &[f32], v1: &[f32]) -> Result<()> {
        self.mirror.splice_slot(slot, k1, v1)
    }

    fn decode(&mut self, tokens: &[u32], pos: &[u32]) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), self.cfg.batch);
        assert_eq!(pos.len(), self.cfg.batch);
        self.steps += 1;
        let mut out = Vec::with_capacity(self.cfg.batch * self.cfg.vocab);
        for (slot, &t) in tokens.iter().enumerate() {
            let next = (t + slot as u32 + 1) % self.cfg.vocab as u32;
            out.extend_from_slice(&self.onehot(next));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut b = MockBackend::new(2, 16, 32);
        let (l1, k1, _) = b.prefill(&[3, 4]).unwrap();
        let (l2, _, _) = b.prefill(&[3, 4]).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(k1[0], 3.0);
        assert_eq!(b.prefills, 2);
    }

    #[test]
    fn mock_decode_advances_per_slot() {
        let mut b = MockBackend::new(2, 16, 32);
        let logits = b.decode(&[5, 5], &[0, 0]).unwrap();
        let row = |s: usize| &logits[s * 32..(s + 1) * 32];
        assert_eq!(crate::coordinator::sampler::argmax(row(0)), 6);
        assert_eq!(crate::coordinator::sampler::argmax(row(1)), 7);
    }
}
