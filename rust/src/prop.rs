//! Tiny property-testing harness (offline build: no proptest).
//!
//! `forall` runs a seeded generator/check loop and reports the failing
//! seed + case index on the first counterexample, so failures are
//! reproducible (`forall_seeded` replays a single case). Shrinking is
//! intentionally out of scope — generators here produce small cases by
//! construction.

use crate::rng::Rng;

/// Default case count for property tests.
pub const DEFAULT_CASES: usize = 100;

/// Run `check(gen(rng))` for `cases` seeded cases. Panics with the
/// case's replay seed on failure.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Replay a single case by seed (for debugging a `forall` failure).
pub fn forall_seeded<T, G, C>(case_seed: u64, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(case_seed);
    let input = gen(&mut rng);
    if let Err(msg) = check(&input) {
        panic!("property failed (seed {case_seed:#x}): {msg}\ninput: {input:?}");
    }
}

/// Generator helpers shared by property tests across the crate.
pub mod gen {
    use crate::rng::Rng;

    /// Random symbol vector with a random distribution shape.
    pub fn symbols(rng: &mut Rng, max_len: usize) -> Vec<u8> {
        let n = 1 + rng.below(max_len);
        match rng.below(4) {
            0 => (0..n).map(|_| rng.below(256) as u8).collect(),
            1 => (0..n).map(|_| rng.below(16) as u8).collect(),
            2 => {
                // Heavy mode + tail.
                (0..n)
                    .map(|_| {
                        if rng.f32() < 0.85 {
                            7
                        } else {
                            rng.below(256) as u8
                        }
                    })
                    .collect()
            }
            _ => {
                // Discretized Gaussian.
                (0..n)
                    .map(|_| {
                        let g = rng.gaussian_f32(128.0, 24.0);
                        g.round().clamp(0.0, 255.0) as u8
                    })
                    .collect()
            }
        }
    }

    /// Random weight vector (various spans/signs) for quantizer tests.
    pub fn weights(rng: &mut Rng, max_len: usize) -> Vec<f32> {
        let n = 1 + rng.below(max_len);
        match rng.below(4) {
            0 => rng.gaussian_vec(n, 0.0, 0.08),
            1 => (0..n).map(|_| rng.range_f32(0.0, 1.0)).collect(),
            2 => (0..n).map(|_| rng.range_f32(-3.0, -0.5)).collect(),
            _ => rng.gaussian_vec(n, 0.4, 1.5),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            1,
            50,
            |rng| rng.below(100),
            |&n| {
                if n < 100 {
                    Ok(())
                } else {
                    Err(format!("{n} >= 100"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_counterexample() {
        forall(
            2,
            50,
            |rng| rng.below(10),
            |&n| if n < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn generators_produce_valid_ranges() {
        let mut rng = crate::rng::Rng::new(3);
        for _ in 0..50 {
            let s = gen::symbols(&mut rng, 100);
            assert!(!s.is_empty() && s.len() <= 100);
            let w = gen::weights(&mut rng, 100);
            assert!(!w.is_empty() && w.len() <= 100);
            assert!(w.iter().all(|x| x.is_finite()));
        }
    }
}
