//! Cross-module integration tests over the real AOT artifacts.
//!
//! These run only when `make artifacts` has produced `artifacts/` (they
//! are skipped otherwise so `cargo test` works on a fresh checkout).
//! They close the loop the unit tests can't: rust PJRT execution must
//! reproduce the python-side golden outputs bit-for-bit-ish, and the
//! compressed serving path must be lossless end to end.

use entrollm::coordinator::{Backend, Engine, EngineConfig, Request};
use entrollm::corpus::ByteTokenizer;
use entrollm::decode::ParallelDecoder;
use entrollm::json::Value;
use entrollm::pipeline::{build_elm, load_backend, split_weights, Flavor};
use entrollm::quant::BitWidth;
use entrollm::runtime::{load_weights_bin, Manifest, ModelRuntime, Variant, WeightSet};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn golden(dir: &Path) -> Value {
    Manifest::load_golden(dir).expect("golden.json")
}

/// Rust prefill logits must match the python golden head values.
#[test]
fn prefill_matches_python_golden_f32_and_quant() {
    let Some(dir) = artifacts_dir() else { return };
    let g = golden(&dir);
    let prompt: Vec<u32> = g
        .get("prompt_tokens")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect();

    for (flavor, tag, tol) in [
        (Flavor::F32, "f32", 1e-3f32),
        (Flavor::U8, "u8", 1e-2),
        (Flavor::U4, "u4", 1e-2),
    ] {
        let (backend, _) = load_backend(&dir, flavor, 2).unwrap();
        let out = backend.runtime().prefill(&prompt).unwrap();
        let want: Vec<f32> = g
            .get("variants")
            .unwrap()
            .get(tag)
            .unwrap()
            .get("prefill_logits_head")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        for (i, (a, b)) in out.logits.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < tol.max(b.abs() * 0.02),
                "{tag} logit[{i}]: rust {a} vs python {b}"
            );
        }
        // Argmax agreement is the functional bar.
        let am = entrollm::coordinator::sampler::argmax(&out.logits);
        let want_am = g
            .get("variants")
            .unwrap()
            .get(tag)
            .unwrap()
            .get("prefill_argmax")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(am, want_am, "{tag} prefill argmax");
    }
}

/// Rust eval-ppl must reproduce the python golden perplexities.
#[test]
fn eval_ppl_matches_python_golden() {
    let Some(dir) = artifacts_dir() else { return };
    let g = golden(&dir);
    let n_win = g.get("eval_windows").unwrap().as_usize().unwrap();
    for (flavor, tag) in [(Flavor::F32, "f32"), (Flavor::U8, "u8"), (Flavor::U4, "u4")] {
        let (_, ppl) = entrollm::pipeline::eval_ppl(&dir, flavor, 2, n_win).unwrap();
        let want = g
            .get("variants")
            .unwrap()
            .get(tag)
            .unwrap()
            .get("eval_char_ppl")
            .unwrap()
            .as_f64()
            .unwrap();
        let rel = (ppl - want).abs() / want;
        assert!(rel < 0.05, "{tag}: rust ppl {ppl} vs python {want} (rel {rel})");
    }
}

/// The Table I quality ordering must hold on the rust side too.
#[test]
fn quality_ordering_f32_u8_u4() {
    let Some(dir) = artifacts_dir() else { return };
    let ppl = |f: Flavor| entrollm::pipeline::eval_ppl(&dir, f, 2, 8).unwrap().1;
    let (p32, p8, p4) = (ppl(Flavor::F32), ppl(Flavor::U8), ppl(Flavor::U4));
    assert!(p32 <= p8 * 1.02, "u8 ({p8}) must track f32 ({p32})");
    assert!(p8 < p4, "u4 ({p4}) must degrade vs u8 ({p8})");
}

/// Compress → save → load → parallel-decode must be lossless and the
/// decoded weight set must serve identical logits to direct quantization.
#[test]
fn elm_roundtrip_preserves_serving_logits() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join(format!("elm_it_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let elm_path = tmp.join("model_u8.elm");
    let (model, report) = build_elm(&dir, BitWidth::U8).unwrap();
    assert!(report.effective_bits < 8.0);
    model.save(&elm_path).unwrap();

    let (backend, stats) =
        entrollm::pipeline::load_backend_from_elm(&dir, &elm_path, 3).unwrap();
    assert_eq!(stats.total_symbols(), report.n_params);

    let (direct, _) = load_backend(&dir, Flavor::U8, 2).unwrap();
    let prompt = ByteTokenizer.encode("the model runs on the edge");
    let a = backend.runtime().prefill(&prompt).unwrap();
    let b = direct.runtime().prefill(&prompt).unwrap();
    assert_eq!(a.logits.len(), b.logits.len());
    for (x, y) in a.logits.iter().zip(&b.logits) {
        assert!((x - y).abs() < 1e-5, "elm-roundtrip logits must be identical");
    }
    std::fs::remove_dir_all(&tmp).ok();
}

/// Full serving engine over the real quant backend: batch of prompts,
/// continuous refill, deterministic greedy outputs.
#[test]
fn engine_serves_batch_on_quant_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let (backend, _) = load_backend(&dir, Flavor::U8, 2).unwrap();
    let batch = backend.cfg().batch;
    let mut engine = Engine::new(backend, EngineConfig::default());
    let tok = ByteTokenizer;
    let prompts = [
        "the model runs on",
        "memory bandwidth is",
        "huffman decode of the",
        "edge device inference",
        "parallel threads decode",
        "quantized weight symbols",
    ];
    for (i, p) in prompts.iter().enumerate() {
        engine
            .submit(Request::greedy(i as u64, tok.encode(p), 8))
            .unwrap();
    }
    let responses = engine.run_to_completion(10_000).unwrap();
    assert_eq!(responses.len(), prompts.len());
    for r in &responses {
        assert_eq!(r.tokens.len(), 8, "greedy budget respected");
        assert!(r.tokens.iter().all(|&t| t < 128));
    }
    // Continuous batching actually batched (6 requests, B slots).
    assert!(engine.stats().mean_occupancy() > 1.0);
    assert!(engine.stats().decode_steps < 7 * 8);
    let _ = batch;

    // Determinism: rerun one prompt, same output.
    let (backend2, _) = load_backend(&dir, Flavor::U8, 2).unwrap();
    let mut engine2 = Engine::new(backend2, EngineConfig::default());
    engine2
        .submit(Request::greedy(0, tok.encode(prompts[0]), 8))
        .unwrap();
    let r2 = engine2.run_to_completion(10_000).unwrap();
    let r1 = responses.iter().find(|r| r.id == 0).unwrap();
    assert_eq!(r1.tokens, r2[0].tokens, "greedy generation is deterministic");
}

/// uint4 serving also works (same HLO, smaller symbols).
#[test]
fn u4_flavor_serves() {
    let Some(dir) = artifacts_dir() else { return };
    let (backend, stats) = load_backend(&dir, Flavor::U4, 2).unwrap();
    assert!(stats.is_some());
    let mut engine = Engine::new(backend, EngineConfig::default());
    engine
        .submit(Request::greedy(1, ByteTokenizer.encode("the edge"), 6))
        .unwrap();
    let rs = engine.run_to_completion(1000).unwrap();
    assert_eq!(rs.len(), 1);
    assert_eq!(rs[0].tokens.len(), 6);
}

/// Effective-bits on the real trained weights land in a sane band and
/// u4 compresses (relatively) harder than u8 — Table I's storage story.
#[test]
fn table1_effective_bits_on_real_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let (m8, r8) = build_elm(&dir, BitWidth::U8).unwrap();
    let (m4, r4) = build_elm(&dir, BitWidth::U4).unwrap();
    assert!(r8.effective_bits < 8.0 && r8.effective_bits > 3.0, "{}", r8.effective_bits);
    assert!(r4.effective_bits < 4.0 && r4.effective_bits > 0.5, "{}", r4.effective_bits);
    // Relative saving is stronger at 4-bit (paper: 30% vs 65%).
    let save8 = 1.0 - r8.effective_bits / 8.0;
    let save4 = 1.0 - r4.effective_bits / 4.0;
    assert!(save4 > save8, "u4 saving {save4} vs u8 {save8}");
    assert_eq!(m8.n_params(), m4.n_params());
}

/// Parallel decode of the real model is lossless for any thread count.
#[test]
fn parallel_decode_real_model_all_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let (model, _) = build_elm(&dir, BitWidth::U4).unwrap();
    let (base, _) = ParallelDecoder::new(1).decode_model(&model).unwrap();
    for threads in [2, 4, 8] {
        let (out, stats) = ParallelDecoder::new(threads).decode_model(&model).unwrap();
        assert_eq!(stats.threads.len(), threads);
        for (a, b) in base.iter().zip(&out) {
            assert_eq!(a.symbols.data(), b.symbols.data());
        }
    }
}

/// The weight split honors the manifest's quantized-name list.
#[test]
fn split_weights_partitions_by_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(dir.join("manifest.json")).unwrap();
    let weights = load_weights_bin(dir.join("weights.bin")).unwrap();
    let total = weights.len();
    let (q, rest) = split_weights(&manifest, weights);
    assert_eq!(q.len(), manifest.quantized_names.len());
    assert_eq!(q.len() + rest.len(), total);
    assert!(rest.iter().all(|(n, _)| n.contains("ln")));
}

/// WeightSet must reject a mismatched manifest arg (fail closed).
#[test]
fn weightset_missing_tensor_fails_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let ws = WeightSet::from_f32(vec![]);
    let err = ModelRuntime::load(&dir, Variant::F32, &ws);
    assert!(err.is_err());
}

/// With real artifacts: `load_backend_streaming` must serve logits
/// identical to the eager `load_backend_from_elm` on the same container.
#[test]
fn streaming_backend_matches_eager_backend_on_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join(format!("elm_stream_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let elm_path = tmp.join("model_u8.elm");
    let (model, _) = build_elm(&dir, BitWidth::U8).unwrap();
    model.save(&elm_path).unwrap();

    let (eager, _) = entrollm::pipeline::load_backend_from_elm(&dir, &elm_path, 4).unwrap();
    let (streaming, stats) =
        entrollm::pipeline::load_backend_streaming(&dir, &elm_path, 4, 2).unwrap();
    assert!(stats.max_layers_ahead <= 2);
    assert_eq!(stats.total_symbols(), model.n_params());

    let prompt = ByteTokenizer.encode("the model streams weights layer by layer");
    let a = eager.runtime().prefill(&prompt).unwrap();
    let b = streaming.runtime().prefill(&prompt).unwrap();
    for (x, y) in a.logits.iter().zip(&b.logits) {
        assert!((x - y).abs() < 1e-6, "streaming logits must be identical");
    }

    // Same greedy tokens end to end.
    let run = |backend: entrollm::coordinator::PjrtBackend| -> Vec<u32> {
        let mut engine = Engine::new(backend, EngineConfig::default());
        engine
            .submit(Request::greedy(1, ByteTokenizer.encode("the edge"), 8))
            .unwrap();
        engine.run_to_completion(10_000).unwrap().remove(0).tokens
    };
    assert_eq!(run(eager), run(streaming));
    std::fs::remove_dir_all(&tmp).ok();
}

/// The streaming load path is lossless at the **token** level, with no
/// artifacts needed: `DigestBackend`'s generation is a pure function of
/// the weight bits, so eager-loaded and streaming-loaded weight sets
/// generate identical tokens iff the decoded weights are bit-identical.
#[test]
fn streaming_load_serves_identical_tokens_to_eager_load() {
    use entrollm::coordinator::DigestBackend;
    use entrollm::decode::StreamingDecoder;
    use entrollm::pipeline::synthetic_layers;
    use entrollm::store::compress;
    use std::sync::Arc;

    let layers = synthetic_layers(12, 0xA11CE);
    let (elm, _) = compress(&layers, BitWidth::U8).unwrap();
    let elm = Arc::new(elm);

    // Eager: barrier decode, then build the weight set at once.
    let (tensors, _) = ParallelDecoder::new(4).decode_model(&elm).unwrap();
    let named: Vec<_> = elm
        .layers
        .iter()
        .map(|m| m.name.clone())
        .zip(tensors)
        .collect();
    let eager_ws = WeightSet::from_quantized(named, vec![]);

    // Streaming: bounded-prefetch decode, layers installed as they arrive.
    let mut stream = StreamingDecoder::new(3, 2)
        .stream(Arc::clone(&elm))
        .unwrap();
    let stream_ws = WeightSet::from_layer_stream(&mut stream, vec![]).unwrap();
    let stats = stream.into_stats();
    assert!(stats.max_layers_ahead <= 2, "prefetch bound violated");

    let run = |ws: &WeightSet| -> Vec<Vec<u32>> {
        let backend = DigestBackend::from_weights(ws, 2, 64, 128);
        let mut engine = Engine::new(backend, EngineConfig::default());
        let prompts = ["the edge model", "streams weights", "layer by layer"];
        for (i, p) in prompts.iter().enumerate() {
            engine
                .submit(Request::greedy(i as u64, ByteTokenizer.encode(p), 12))
                .unwrap();
        }
        let mut rs = engine.run_to_completion(10_000).unwrap();
        rs.sort_by_key(|r| r.id);
        rs.into_iter().map(|r| r.tokens).collect()
    };
    let eager_tokens = run(&eager_ws);
    let stream_tokens = run(&stream_ws);
    assert_eq!(
        eager_tokens, stream_tokens,
        "streaming load must be lossless at the token level"
    );
    assert!(eager_tokens.iter().all(|t| t.len() == 12));
}
