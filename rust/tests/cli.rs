//! End-to-end tests of the `entrollm` CLI binary (subprocess level):
//! the exact commands a user runs, against the real artifacts.

use std::path::{Path, PathBuf};
use std::process::Command;

fn bin() -> PathBuf {
    // target/release|debug/entrollm next to this test binary.
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // release|debug/
    p.push(if cfg!(windows) { "entrollm.exe" } else { "entrollm" });
    p
}

fn have_artifacts() -> bool {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn entrollm");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_commands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    for cmd in ["compress", "inspect", "serve", "latency", "eval-ppl"] {
        assert!(text.contains(cmd), "help must mention {cmd}");
    }
}

#[test]
fn unknown_command_fails_with_message() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn latency_runs_without_artifacts() {
    let (ok, text) = run(&["latency", "--params", "1e9"]);
    assert!(ok, "{text}");
    assert!(text.contains("token gen"));
    assert!(text.contains("uint4"));
}

#[test]
fn compress_inspect_decode_bench_pipeline() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let tmp = std::env::temp_dir().join(format!("cli_elm_{}.elm", std::process::id()));
    let tmp_s = tmp.to_str().unwrap();

    let (ok, text) = run(&["compress", "--bits", "u4", "--out", tmp_s]);
    assert!(ok, "{text}");
    assert!(text.contains("effective bits"), "{text}");

    let (ok, text) = run(&["inspect", "--model", tmp_s, "--histogram"]);
    assert!(ok, "{text}");
    assert!(text.contains("ELM container"), "{text}");
    assert!(text.contains("symbol stats"), "{text}");

    let (ok, text) = run(&["decode-bench", "--model", tmp_s, "--threads", "2", "--repeat", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("Msym/s"), "{text}");

    std::fs::remove_file(&tmp).ok();
}

/// Artifact-free roundtrip: synthetic compress → inspect → decompress,
/// asserting CRC-clean segments and that the recovered quantized
/// weights are byte-identical across the parallel and streaming decode
/// paths (the streaming losslessness claim, at subprocess level).
#[test]
fn synthetic_compress_inspect_decompress_roundtrip() {
    let dir = std::env::temp_dir().join(format!("cli_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let elm = dir.join("model.elm");
    let elm_s = elm.to_str().unwrap();

    let (ok, text) = run(&[
        "compress", "--synthetic", "10", "--seed", "7", "--bits", "u4", "--out", elm_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("synthetic model: 10 layers"), "{text}");
    assert!(text.contains("effective bits"), "{text}");

    // Inspect decodes every layer behind CRC verification.
    let (ok, text) = run(&["inspect", "--model", elm_s, "--histogram"]);
    assert!(ok, "{text}");
    assert!(text.contains("ELM container"), "{text}");
    assert!(text.contains("symbol stats"), "{text}");

    // Decompress twice: eager serial-ish vs streaming with a window.
    let out_a = dir.join("a.eqw");
    let out_b = dir.join("b.eqw");
    let (ok, text) = run(&[
        "decompress", "--model", elm_s, "--out", out_a.to_str().unwrap(), "--threads", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("CRC-clean"), "{text}");
    let (ok, text) = run(&[
        "decompress",
        "--model",
        elm_s,
        "--out",
        out_b.to_str().unwrap(),
        "--threads",
        "4",
        "--prefetch-layers",
        "3",
        "--stream",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("streaming decode"), "{text}");
    assert!(text.contains("CRC-clean"), "{text}");

    let a = std::fs::read(&out_a).unwrap();
    let b = std::fs::read(&out_b).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "recovered quantized weights must be byte-identical");
    assert_eq!(&a[..4], b"EQW1");

    // A corrupted container must fail decompression (CRC catches it).
    let mut bytes = std::fs::read(&elm).unwrap();
    let n = bytes.len();
    bytes[n - 3] ^= 0xFF; // payload tail: flips a segment byte
    let bad = dir.join("bad.elm");
    std::fs::write(&bad, &bytes).unwrap();
    let (ok, text) = run(&[
        "decompress", "--model", bad.to_str().unwrap(), "--out", dir.join("c.eqw").to_str().unwrap(),
    ]);
    assert!(!ok, "corrupted container must fail: {text}");
    assert!(text.contains("CRC"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

/// The `--codec` flag end-to-end: the same synthetic model compressed
/// with every codec choice must decompress — on both the parallel and
/// the streaming path — to byte-identical EQW dumps, `inspect` must
/// name the codec, and a bogus `--codec` value must fail at parse.
#[test]
fn codec_flag_cross_codec_decompress_is_bitexact() {
    let dir = std::env::temp_dir().join(format!("cli_codec_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Tiled (multi-tile layers) so the v3 per-tile codec bytes and the
    // parallel tile scheduler are both on the tested path.
    let mut dumps: Vec<Vec<u8>> = Vec::new();
    for codec in ["huffman", "ans", "auto"] {
        let elm = dir.join(format!("{codec}.elm"));
        let elm_s = elm.to_str().unwrap();
        let (ok, text) = run(&[
            "compress", "--synthetic", "9", "--seed", "21", "--bits", "u4", "--tile-kb",
            "0.5", "--codec", codec, "--out", elm_s,
        ]);
        assert!(ok, "compress --codec {codec}: {text}");
        assert!(text.contains("encoded payload"), "{text}");

        let (ok, text) = run(&["inspect", "--model", elm_s]);
        assert!(ok, "{text}");
        assert!(text.contains("codecs"), "{text}");
        if codec == "ans" {
            assert!(text.contains("tans"), "inspect must name tans: {text}");
        }

        let eager = dir.join(format!("{codec}_eager.eqw"));
        let (ok, text) = run(&[
            "decompress", "--model", elm_s, "--out", eager.to_str().unwrap(), "--threads", "4",
        ]);
        assert!(ok, "{text}");
        assert!(text.contains("CRC-clean"), "{text}");
        let streamed = dir.join(format!("{codec}_stream.eqw"));
        let (ok, text) = run(&[
            "decompress",
            "--model",
            elm_s,
            "--out",
            streamed.to_str().unwrap(),
            "--threads",
            "2",
            "--prefetch-layers",
            "3",
            "--stream",
        ]);
        assert!(ok, "{text}");
        assert!(text.contains("streaming decode"), "{text}");

        let a = std::fs::read(&eager).unwrap();
        let b = std::fs::read(&streamed).unwrap();
        assert_eq!(a, b, "--codec {codec}: parallel vs streaming dumps differ");
        dumps.push(a);
    }
    assert_eq!(
        dumps[0], dumps[1],
        "huffman and tans containers must decode to identical weights"
    );
    assert_eq!(dumps[0], dumps[2], "auto must decode to identical weights");

    let (ok, text) = run(&[
        "compress", "--synthetic", "2", "--codec", "brotli", "--out",
        dir.join("x.elm").to_str().unwrap(),
    ]);
    assert!(!ok, "bogus codec must fail: {text}");
    assert!(text.contains("--codec"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Artifact-free residency serving: a synthetic model generates through
/// the LRU weight cache under a sub-model byte budget, and the CLI
/// reports the cache counters.
#[test]
fn generate_with_weight_budget_serves_synthetic_model() {
    let (ok, text) = run(&[
        "generate",
        "--synthetic",
        "10",
        "--seed",
        "3",
        "--weight-budget-mb",
        "0.02",
        "--prompt",
        "hi",
        "--max-tokens",
        "6",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("synthetic model: 10 layers"), "{text}");
    assert!(text.contains("weight-residency cache"), "{text}");
    assert!(text.contains("response 1"), "{text}");
    assert!(text.contains("cache:"), "{text}");
}

/// Decode-ahead serving through the CLI: `--decode-ahead N` prefetches
/// layer `i+1` while layer `i` is consumed, and the run report carries
/// the prefetch counters next to the cache counters.
#[test]
fn generate_with_decode_ahead_prefetches_and_reports_counters() {
    let (ok, text) = run(&[
        "generate",
        "--synthetic",
        "10",
        "--seed",
        "3",
        "--weight-budget-mb",
        "0.06",
        "--decode-ahead",
        "2",
        "--prompt",
        "hi",
        "--max-tokens",
        "6",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("synthetic model: 10 layers"), "{text}");
    assert!(text.contains("decode-ahead prefetch: window 2 layers"), "{text}");
    assert!(text.contains("response 1"), "{text}");
    assert!(text.contains("cache:"), "{text}");
    assert!(text.contains("prefetch:"), "{text}");
}

/// `--decode-ahead` with the same prompt/seed/budget must generate the
/// exact same text as the fault-on-demand path — prefetch changes
/// *when* layers decode, never *what* they decode to.
#[test]
fn decode_ahead_generation_is_token_identical_to_fault_on_demand() {
    let base = [
        "generate",
        "--synthetic",
        "8",
        "--seed",
        "11",
        "--weight-budget-mb",
        "0.08",
        "--prompt",
        "edge",
        "--max-tokens",
        "8",
    ];
    let (ok, plain) = run(&base);
    assert!(ok, "{plain}");
    let mut ahead_args: Vec<&str> = base.to_vec();
    ahead_args.extend_from_slice(&["--decode-ahead", "2"]);
    let (ok, ahead) = run(&ahead_args);
    assert!(ok, "{ahead}");
    let text_of = |out: &str| -> String {
        // The generated text is the line after the response header.
        let mut lines = out.lines();
        lines.find(|l| l.starts_with("--- response")).expect("response header");
        lines.next().expect("generated text").to_string()
    };
    assert_eq!(text_of(&plain), text_of(&ahead), "plain:\n{plain}\nahead:\n{ahead}");
}

/// A zero-layer container decompresses to a valid *empty* EQW dump
/// (exit 0 AND an output file), on both the eager and the streaming
/// path — regression for the streaming path silently writing nothing.
#[test]
fn decompress_zero_layer_container_writes_valid_empty_eqw() {
    use entrollm::huffman::CodeSpec;
    use entrollm::quant::BitWidth;
    use entrollm::store::ElmModel;

    let dir = std::env::temp_dir().join(format!("cli_zero_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let elm = dir.join("zero.elm");
    let mut one = [0u8; 256];
    one[0] = 1;
    ElmModel {
        bits: BitWidth::U8,
        code: CodeSpec::from_lengths(&one).unwrap(),
        ans: None,
        layers: Vec::new(),
        payload: Vec::new(),
    }
    .save(&elm)
    .unwrap();

    // Every reader must accept the container, not just decompress.
    let (ok, text) = run(&["inspect", "--model", elm.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("0 layers"), "{text}");
    assert!(text.contains("empty weight set"), "{text}");

    // "EQW1" | u8 bitwidth | u32 n_layers=0, nothing else.
    let want: Vec<u8> = [b'E', b'Q', b'W', b'1', 8u8, 0, 0, 0, 0].to_vec();

    let out_eager = dir.join("eager.eqw");
    let (ok, text) = run(&[
        "decompress",
        "--model",
        elm.to_str().unwrap(),
        "--out",
        out_eager.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("decoded 0 layers"), "{text}");
    assert_eq!(std::fs::read(&out_eager).unwrap(), want);

    let out_stream = dir.join("stream.eqw");
    let (ok, text) = run(&[
        "decompress",
        "--model",
        elm.to_str().unwrap(),
        "--out",
        out_stream.to_str().unwrap(),
        "--stream",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("streaming decode"), "{text}");
    assert_eq!(
        std::fs::read(&out_stream).unwrap(),
        want,
        "streaming path must write the same valid empty weight set"
    );

    std::fs::remove_dir_all(&dir).ok();
}

/// A budget smaller than one decoded layer must fail up front with the
/// thrash explanation, not hang or loop.
#[test]
fn weight_budget_below_one_layer_fails_cleanly() {
    let (ok, text) = run(&[
        "generate",
        "--synthetic",
        "10",
        "--seed",
        "3",
        "--weight-budget-mb",
        "0.0001",
        "--prompt",
        "hi",
    ]);
    assert!(!ok, "must fail: {text}");
    assert!(text.contains("thrash"), "{text}");
}

/// QoS config errors on the multi-model path surface at startup —
/// before the server ever binds a port — with messages naming the
/// problem: reserves past the budget, malformed `--model` options,
/// and bogus admission weights.
#[test]
fn multi_model_qos_rejects_bad_configs_at_startup() {
    let dir = std::env::temp_dir().join(format!("cli_qos_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let a = dir.join("a.elm");
    let b = dir.join("b.elm");
    for (path, seed) in [(&a, "1"), (&b, "2")] {
        let (ok, text) = run(&[
            "compress", "--synthetic", "6", "--seed", seed, "--out", path.to_str().unwrap(),
        ]);
        assert!(ok, "{text}");
    }
    let (a_s, b_s) = (a.to_str().unwrap(), b.to_str().unwrap());

    // Reservations summing past the global budget: rejected loudly.
    let (ok, text) = run(&[
        "serve",
        &format!("--model=alpha={a_s},reserve-mb=40"),
        &format!("--model=beta={b_s},reserve-mb=40"),
        "--weight-budget-mb",
        "64",
    ]);
    assert!(!ok, "must fail: {text}");
    assert!(text.contains("reservations"), "{text}");

    // Unknown --model option.
    let (ok, text) = run(&[
        "serve",
        &format!("--model=alpha={a_s},bogus=3"),
        &format!("--model=beta={b_s}"),
    ]);
    assert!(!ok, "must fail: {text}");
    assert!(text.contains("unknown option"), "{text}");

    // Non-positive admission weight: rejected, naming the model.
    let (ok, text) = run(&[
        "serve",
        &format!("--model=alpha={a_s},weight=0"),
        &format!("--model=beta={b_s}"),
        "--weight-budget-mb",
        "64",
    ]);
    assert!(!ok, "must fail: {text}");
    assert!(text.contains("weight"), "{text}");

    // Negative reserve: rejected at parse.
    let (ok, text) = run(&[
        "serve",
        &format!("--model=alpha={a_s},reserve-mb=-1"),
        &format!("--model=beta={b_s}"),
    ]);
    assert!(!ok, "must fail: {text}");
    assert!(text.contains("reserve-mb"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_ppl_quality_ordering_via_cli() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let ppl = |flavor: &str| -> f64 {
        let (ok, text) = run(&["eval-ppl", "--flavor", flavor, "--windows", "4"]);
        assert!(ok, "{text}");
        // "...| char-ppl 4.4399 (4 windows)"
        let marker = "char-ppl ";
        let i = text.find(marker).expect("ppl line") + marker.len();
        text[i..]
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .expect("ppl number")
    };
    let p8 = ppl("u8");
    let p4 = ppl("u4");
    assert!(p8 < p4, "u8 ppl {p8} must beat u4 {p4}");
}

#[test]
fn generate_produces_text() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (ok, text) = run(&[
        "generate",
        "--flavor",
        "u8",
        "--prompt",
        "the model",
        "--max-tokens",
        "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("response 1"), "{text}");
    assert!(text.contains("8 tokens"), "{text}");
}
