//! In-tree documentation link checker: every *relative* markdown link
//! in `README.md` and `docs/*.md` must point at a file that exists in
//! the checkout, and every `#anchor` must match a heading in its
//! target file. No network: external (`http://`, `https://`,
//! `mailto:`) links are deliberately out of scope — CI must not fetch.
//!
//! This is the checker the CI `docs` job runs
//! (`cargo test --test doc_links`); it also runs under plain
//! `cargo test`, so a dangling link fails locally before it ships.

use std::fs;
use std::path::{Path, PathBuf};

/// Repository root: the crate lives in `rust/`, docs one level up.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf()
}

/// The documentation set under check: the README plus every markdown
/// file in `docs/`.
fn doc_files() -> Vec<PathBuf> {
    let root = repo_root();
    let mut files = vec![root.join("README.md")];
    let docs = root.join("docs");
    let mut entries: Vec<_> = fs::read_dir(&docs)
        .unwrap_or_else(|e| panic!("read {}: {e}", docs.display()))
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().is_some_and(|x| x == "md"))
        .collect();
    entries.sort();
    files.extend(entries);
    files
}

/// Strip fenced code blocks (``` ... ```): shell comments inside
/// fences look like headings, and fenced text can contain `](`.
fn without_code_fences(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut fenced = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            fenced = !fenced;
            continue;
        }
        if !fenced {
            out.push_str(line);
            out.push('\n');
        }
    }
    out
}

/// Extract inline markdown link targets: every `](target)`.
fn link_targets(text: &str) -> Vec<String> {
    let mut targets = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while let Some(j) = text[i..].find("](") {
        let start = i + j + 2;
        let Some(len) = text[start..].find(')') else {
            break;
        };
        if bytes[start..start + len].iter().all(|b| !b.is_ascii_whitespace()) {
            targets.push(text[start..start + len].to_string());
        }
        i = start + len + 1;
    }
    targets
}

/// GitHub-style anchor slug: lowercase, alphanumerics / `-` / `_`
/// kept, spaces become hyphens, everything else dropped.
fn slug(heading: &str) -> String {
    heading
        .trim()
        .chars()
        .filter_map(|c| match c {
            ' ' => Some('-'),
            '-' | '_' => Some(c),
            c if c.is_ascii_alphanumeric() => Some(c.to_ascii_lowercase()),
            _ => None,
        })
        .collect()
}

/// All heading slugs of a markdown file (ATX headings outside fences).
fn heading_slugs(text: &str) -> Vec<String> {
    without_code_fences(text)
        .lines()
        .filter_map(|l| {
            let h = l.trim_start().trim_start_matches('#');
            (h.len() < l.trim_start().len()).then(|| slug(h))
        })
        .collect()
}

fn is_external(target: &str) -> bool {
    ["http://", "https://", "mailto:"].iter().any(|p| target.starts_with(p))
}

#[test]
fn every_relative_doc_link_resolves() {
    let mut checked = 0usize;
    let mut errors = Vec::new();
    for file in doc_files() {
        let text = fs::read_to_string(&file)
            .unwrap_or_else(|e| panic!("read {}: {e}", file.display()));
        let dir = file.parent().expect("doc file has a parent directory");
        for target in link_targets(&without_code_fences(&text)) {
            if is_external(&target) || target.is_empty() {
                continue;
            }
            checked += 1;
            let (path_part, anchor) = match target.split_once('#') {
                Some((p, a)) => (p, Some(a.to_string())),
                None => (target.as_str(), None),
            };
            // Resolve the file half relative to the linking document.
            let linked = if path_part.is_empty() {
                file.clone()
            } else {
                let resolved = dir.join(path_part);
                if !resolved.is_file() {
                    errors.push(format!(
                        "{}: link '{target}' -> missing file {}",
                        file.display(),
                        resolved.display()
                    ));
                    continue;
                }
                resolved
            };
            // Resolve the anchor half against the target's headings.
            if let Some(anchor) = anchor {
                let linked_text = fs::read_to_string(&linked)
                    .unwrap_or_else(|e| panic!("read {}: {e}", linked.display()));
                if !heading_slugs(&linked_text).contains(&anchor) {
                    errors.push(format!(
                        "{}: link '{target}' -> no heading '#{anchor}' in {}",
                        file.display(),
                        linked.display()
                    ));
                }
            }
        }
    }
    assert!(errors.is_empty(), "dangling doc links:\n{}", errors.join("\n"));
    // The docs genuinely cross-link; an empty scan means the extractor
    // broke, not that the docs went linkless.
    assert!(checked >= 8, "only {checked} relative links found — extractor regressed?");
}

#[test]
fn architecture_map_stays_in_the_doc_set() {
    // ARCHITECTURE.md is the subsystem map this crate's docs hang off;
    // make its presence (and the README's pointer to it) explicit so a
    // doc reshuffle cannot silently drop either.
    let root = repo_root();
    assert!(root.join("docs/ARCHITECTURE.md").is_file());
    let readme = fs::read_to_string(root.join("README.md")).expect("read README.md");
    assert!(
        readme.contains("docs/ARCHITECTURE.md"),
        "README.md no longer links the architecture map"
    );
}
